"""Measurement-backed dispatch: the persistent per-device autotuner.

Contract under test (mirrors the registry corruption suites): a warm
process resolves every decision from the persisted table with **zero**
microbenchmark calls; a cold, missing, or corrupt table always degrades to
the analytic model with a surfaced counter — never an error; and no tuned
decision may ever change a result, only which engine computes it (pinned
property-style on exact-arithmetic integer data, where any legal
split/tier/densify choice yields bit-identical fp32 outputs).
"""
import dataclasses
import glob
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spmm, tuner
from repro.core.cost_model import (
    EngineCostModel, default_cost_model, fringe_ksharded_bytes,
    fringe_resident_bytes,
)
from repro.dynamic import PlanRegistry
from repro.dynamic.tuning import RegistryTuningStore, install_registry_store
from repro.serve import SpmmService
from _hyp import given, settings, st

XLA = spmm.SpmmConfig(impl="xla")


@pytest.fixture(autouse=True)
def _fresh_tuner():
    tuner.reset_for_tests()
    yield
    tuner.reset_for_tests()


def _fake_timer(value=1e-3):
    """Timer double: never runs fn (no compiles), fixed wall time."""
    return lambda fn: value


def _tuned(decisions, **over):
    am = default_cost_model()
    kw = dict(p_matrix=am.p_matrix, p_vector=am.p_vector, r=am.r,
              n_cols=am.n_cols, decisions=decisions)
    kw.update(over)
    return tuner.TunedCostModel(**kw)


# --- resolve modes ---------------------------------------------------------


def test_autotune_off_resolves_analytic_with_zero_benchmarks():
    cm = tuner.resolve_cost_model("spmm", 64, 64, 300, XLA)
    assert type(cm) is EngineCostModel
    assert tuner.tune_call_count() == 0


def test_offline_cold_falls_back_to_analytic_and_counts():
    cfg = dataclasses.replace(XLA, autotune="offline")
    cm = tuner.resolve_cost_model("spmm", 64, 64, 300, cfg)
    assert type(cm) is EngineCostModel  # analytic, not tuned
    assert tuner.tune_call_count() == 0  # offline NEVER benchmarks inline
    assert tuner.tuning_fallback_count() == 1
    assert tuner.get_tuner().counters()["cold_misses"] == 1


def test_inline_measure_then_table_serves_second_resolve():
    tuner.set_timer(_fake_timer())
    cfg = dataclasses.replace(XLA, autotune=True)
    cm = tuner.resolve_cost_model("spmm", 64, 64, 300, cfg)
    assert isinstance(cm, tuner.TunedCostModel) and cm.source == "measured"
    assert tuner.tune_call_count() > 0
    tuner.reset_tune_call_count()
    # same shape class: table-served, zero further microbenchmarks
    cm2 = tuner.resolve_cost_model("spmm", 64, 64, 300, cfg)
    assert isinstance(cm2, tuner.TunedCostModel) and cm2.source == "table"
    assert tuner.tune_call_count() == 0
    assert tuner.get_tuner().counters()["table_hits"] == 1


def test_shape_class_buckets_families_not_exact_shapes():
    a = tuner.shape_class("spmm", 64, 64, 300, XLA)
    assert a == tuner.shape_class("spmm", 60, 50, 280, XLA)  # same buckets
    assert a != tuner.shape_class("spmm", 64, 2048, 300, XLA)
    assert a != tuner.shape_class("sddmm", 64, 64, 300, XLA)


# --- tuned decisions are validated, never load-bearing ---------------------


def test_tuned_resident_preference_demotes_when_it_cannot_fit():
    cm = _tuned({"fringe_tier": ["resident", 0]})
    # the table says resident, but this exact fringe cannot fit the budget:
    # the decision is re-validated and the analytic choice wins
    assert fringe_resident_bytes(20_000, 100, 256) > 12 * 1024 * 1024
    tier, bk = cm.select_fringe_tier(20_000, 100, 256)
    assert (tier, bk) == default_cost_model().select_fringe_tier(
        20_000, 100, 256)


def test_tuned_ksharded_bk_is_clamped_to_the_legal_cap():
    cm = _tuned({"fringe_tier": ["ksharded", 1 << 20]})
    tier, bk = cm.select_fringe_tier(20_000, 100, 256)
    assert tier == "ksharded"
    assert fringe_ksharded_bytes(bk, 100, 256) <= 12 * 1024 * 1024
    assert 2 * bk < 20_000  # strictly cheaper in bytes than resident
    # a shape with no legal bk (k=16: no sublane bk with 2*bk < k) ignores
    # the preference entirely and falls back to the analytic choice
    assert cm.select_fringe_tier(16, 16, 256)[0] == "resident"


def test_tuned_xla_demotion_always_honored():
    cm = _tuned({"fringe_tier": ["xla", 0]})
    assert cm.select_fringe_tier(64, 16, 256) == ("xla", 0)
    assert cm.select_fringe_tier(20_000, 100, 256) == ("xla", 0)


def test_tuned_sddmm_tier_is_demote_only():
    promote = _tuned({"sddmm_tier": "resident"})
    demote = _tuned({"sddmm_tier": "xla"})
    # budget 0: the analytic check says xla; a measured "resident" must
    # not promote past it
    assert promote.select_sddmm_tier(64, 100, 100, vmem_budget=0) == "xla"
    # a measured xla demotion wins even where resident would fit
    assert demote.select_sddmm_tier(64, 100, 100) == "xla"


def test_tuned_thresholds_and_occupancy_come_from_decisions():
    cm = _tuned({
        "delta_max_fraction": 0.4, "delta_max_slowdown": 2.0,
        "densify_occupancy": 0.6, "shard_imbalance_threshold": 1.7,
    })
    assert cm.compaction_thresholds() == (0.4, 2.0)
    assert cm.densify_occupancy() == 0.6
    assert cm.imbalance_threshold() == 1.7
    empty = _tuned({})
    assert empty.compaction_thresholds() == \
        default_cost_model().compaction_thresholds()
    assert empty.densify_occupancy() is None


# --- persistence: registry round-trip, warm process, corruption ------------


def _entry_steps(root):
    name = "tuning-" + tuner.device_fingerprint().replace(":", "_")
    d = os.path.join(root, name)
    return sorted(
        os.path.join(d, s) for s in os.listdir(d) if s.startswith("step_"))


def test_registry_round_trip_warm_process_zero_benchmarks(tmp_path):
    tuner.set_timer(_fake_timer())
    install_registry_store(str(tmp_path))
    cfg = dataclasses.replace(XLA, autotune=True)
    tuner.resolve_cost_model("spmm", 64, 64, 300, cfg)
    assert tuner.tune_call_count() > 0
    assert _entry_steps(str(tmp_path))  # table persisted

    # "new process": fresh tuner state, same store on disk
    tuner.reset_for_tests(keep_store=True)
    cm = tuner.resolve_cost_model("spmm", 64, 64, 300, cfg)
    assert isinstance(cm, tuner.TunedCostModel) and cm.source == "table"
    assert tuner.tune_call_count() == 0  # the acceptance criterion
    assert tuner.get_tuner().counters()["store_errors"] == 0


def test_corrupt_table_degrades_to_analytic_with_counter(tmp_path):
    tuner.set_timer(_fake_timer())
    install_registry_store(str(tmp_path))
    cfg = dataclasses.replace(XLA, autotune=True)
    tuner.resolve_cost_model("spmm", 64, 64, 300, cfg)
    # mangle every retained generation's payload
    for step in _entry_steps(str(tmp_path)):
        for f in glob.glob(os.path.join(step, "*.npy")):
            with open(f, "r+b") as fh:
                fh.truncate(os.path.getsize(f) // 2)

    tuner.reset_for_tests(keep_store=True)
    off = dataclasses.replace(XLA, autotune="offline")
    cm = tuner.resolve_cost_model("spmm", 64, 64, 300, off)
    assert type(cm) is EngineCostModel  # analytic fallback, no raise
    assert tuner.get_tuner().counters()["store_errors"] == 1
    assert tuner.tuning_fallback_count() >= 1


def test_corrupt_newest_generation_falls_back_to_older(tmp_path):
    tuner.set_timer(_fake_timer())
    reg = PlanRegistry(str(tmp_path))
    install_registry_store(reg)
    cfg = dataclasses.replace(XLA, autotune=True)
    tuner.resolve_cost_model("spmm", 64, 64, 300, cfg)
    tuner.resolve_cost_model("spmm", 64, 2048, 3000, cfg)  # second save
    steps = _entry_steps(str(tmp_path))
    assert len(steps) == 2
    for f in glob.glob(os.path.join(steps[-1], "*.npy")):
        with open(f, "r+b") as fh:
            fh.truncate(os.path.getsize(f) // 2)

    tuner.reset_for_tests(keep_store=True)
    install_registry_store(reg)
    off = dataclasses.replace(XLA, autotune="offline")
    cm = tuner.resolve_cost_model("spmm", 64, 64, 300, off)
    assert isinstance(cm, tuner.TunedCostModel)  # served from generation 1
    assert reg.generation_fallbacks == 1
    assert tuner.get_tuner().counters()["store_errors"] == 0


def test_table_from_other_device_is_ignored(tmp_path):
    reg = PlanRegistry(str(tmp_path))
    store = RegistryTuningStore(reg)
    store.save({"other|spmm|m6|k6|d-2|bn256|xla": {
        "table_format_version": tuner.TABLE_FORMAT_VERSION}})
    # rewrite the manifest's device fingerprint so it looks foreign
    import json
    step = _entry_steps(str(tmp_path))[-1]
    manifest_path = os.path.join(step, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    manifest["meta"]["device_fingerprint"] = "tpu:v9"
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)
    assert store.load() is None  # foreign table == absent, not an error


def test_save_failure_is_counted_never_raised():
    class BrokenStore:
        def load(self):
            return None

        def save(self, table):
            raise IOError("disk full")

    tuner.install_store(BrokenStore())
    tuner.set_timer(_fake_timer())
    cfg = dataclasses.replace(XLA, autotune=True)
    cm = tuner.resolve_cost_model("spmm", 64, 64, 300, cfg)
    assert isinstance(cm, tuner.TunedCostModel)  # record still adopted
    assert tuner.get_tuner().counters()["store_errors"] == 1


def test_stale_format_version_records_are_dropped_on_load():
    class Store:
        def __init__(self):
            key = tuner.table_key("spmm", 64, 64, 300, XLA)
            self.table = {key: {"table_format_version": -1}}

        def load(self):
            return self.table

        def save(self, table):
            self.table = table

    tuner.install_store(Store())
    off = dataclasses.replace(XLA, autotune="offline")
    cm = tuner.resolve_cost_model("spmm", 64, 64, 300, off)
    assert type(cm) is EngineCostModel  # stale record never served
    assert tuner.get_tuner().counters()["cold_misses"] == 1


# --- decisions may differ, results may not ---------------------------------


def _exact_coo(rng, m, k, density=0.12):
    """Integer-valued fp32 matrix: any summation order is exact."""
    mask = rng.rand(m, k) < density
    rows, cols = np.nonzero(mask)
    vals = rng.randint(-4, 5, rows.size).astype(np.float64)
    return rows.astype(np.int64), cols.astype(np.int64), vals


def _variant_models():
    am = default_cost_model()
    return [
        _tuned({"fringe_tier": ["xla", 0]}),
        _tuned({}, p_matrix=am.p_matrix * 64),   # vector-hungry split
        _tuned({}, p_vector=am.p_vector * 64),   # matrix-hungry split
        _tuned({"densify_occupancy": 0.05}),
        _tuned({"densify_occupancy": 0.9}),
    ]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(24, 72), st.integers(16, 64))
def test_dispatch_decisions_never_change_results(seed, m, k):
    """Analytic model, tuned table, and forced tiers must agree bitwise.

    Decisions route work between engines; on exact-arithmetic data every
    legal routing produces the identical fp32 output, so any mismatch here
    is a tuned decision changing *what* is computed, not *where*."""
    rng = np.random.RandomState(seed)
    rows, cols, vals = _exact_coo(rng, m, k)
    b = jnp.asarray(rng.randint(-4, 5, (k, 8)).astype(np.float32))
    ref = np.asarray(
        spmm.execute(spmm.prepare(rows, cols, vals, (m, k), XLA), b))
    for cm in _variant_models():
        plan = spmm.prepare(rows, cols, vals, (m, k), XLA, cost_model=cm)
        assert np.array_equal(np.asarray(spmm.execute(plan, b)), ref)
    # forced-tier override through the budget knob
    forced = dataclasses.replace(XLA, fringe_vmem_budget=16)
    plan = spmm.prepare(rows, cols, vals, (m, k), forced)
    assert np.array_equal(np.asarray(spmm.execute(plan, b)), ref)


def test_tuned_table_execution_is_bit_identical(rng):
    """End-to-end through the autotune config path: adopt a table record
    with aggressive decisions, resolve it via autotune="offline", and the
    executed result must match the analytic plan bitwise."""
    rows, cols, vals = _exact_coo(rng, 48, 40)
    b = jnp.asarray(rng.randint(-4, 5, (40, 8)).astype(np.float32))
    ref = np.asarray(
        spmm.execute(spmm.prepare(rows, cols, vals, (48, 40), XLA), b))

    off = dataclasses.replace(XLA, autotune="offline")
    am = default_cost_model()
    key = tuner.table_key("spmm", 48, 40, len(vals), off)
    tuner.get_tuner().adopt(key, {
        "table_format_version": tuner.TABLE_FORMAT_VERSION,
        "p_matrix": am.p_matrix * 64, "p_vector": am.p_vector,
        "r": am.r, "n_cols": am.n_cols, "key": key,
        "decisions": {"fringe_tier": ["xla", 0], "densify_occupancy": 0.9},
    })
    plan = spmm.prepare(rows, cols, vals, (48, 40), off)
    assert np.array_equal(np.asarray(spmm.execute(plan, b)), ref)
    assert tuner.get_tuner().counters()["table_hits"] >= 1


# --- service integration ---------------------------------------------------


def test_service_background_tune_and_warm_health(rng, tmp_path):
    tuner.set_timer(_fake_timer())
    m = k = 64
    mask = rng.rand(m, k) < 0.08
    rows, cols = np.nonzero(mask)
    vals = rng.randn(rows.size)
    reg = PlanRegistry(str(tmp_path))
    cfg = dataclasses.replace(XLA, autotune=True)

    with SpmmService(config=cfg, registry=reg) as svc:
        assert svc.config.autotune == "offline"  # never benchmarks inline
        svc.register("g", rows, cols, vals, (m, k))
        t = svc.submit("g", jnp.asarray(
            rng.randn(k, 8).astype(np.float32)))
        svc.flush()
        svc.fetch(t)
        svc.drain_tunings()
        h = svc.health()
        assert h["stats"]["tunings_scheduled"] == 1
        assert h["stats"]["tunings_applied"] == 1
        assert h["stats"]["tuner_records"] == 1
        assert "tuner_store_errors" in h["stats"]
        assert svc.tuning_report()["records"]

    # warm process: table comes off disk, nothing schedules or measures
    tuner.reset_for_tests(keep_store=True)
    tuner.set_timer(_fake_timer())
    with SpmmService(config=cfg, registry=reg) as svc2:
        svc2.register("g", rows, cols, vals, (m, k))
        svc2.drain_tunings()
        assert svc2.stats.tunings_scheduled == 0
        assert tuner.tune_call_count() == 0
