"""Property-based oracle suite: prepare→execute ≡ dense matmul.

Random COO matrices across density/shape/dtype, checked against the fp64
dense reference for every fringe dispatch tier (resident / K-sharded / XLA
fallback, forced via synthetic VMEM budgets) and both matrix-path variants
(streaming tile einsum vs densified GEMM, forced via density on either side
of the occupancy threshold).  Hypothesis draws a seed + shape knobs and the
arrays come from a seeded RandomState, so examples are cheap to generate and
seed-stable (``derandomize=True``: the same examples every run, CI-fast).

Without hypothesis installed the ``tests/_hyp`` shim skips the ``@given``
tests; the pinned panel below runs the identical checker everywhere.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import spmm
from repro.core.cost_model import fringe_resident_bytes
from _hyp import HAVE_HYPOTHESIS, given, settings, st

BN = 128  # narrow n-blocks keep interpret-mode grids small


def _random_coo(seed, m, k, density):
    rng = np.random.RandomState(seed)
    mask = rng.rand(m, k) < density
    rows, cols = np.nonzero(mask)
    vals = rng.randn(rows.size)
    return rows.astype(np.int64), cols.astype(np.int64), vals


def _force_tier_budget(tier, k_pad, num_rows):
    """VMEM budget that provably forces the given fringe dispatch tier.

    ``k_pad >= 64`` (one bk block) guarantees ``resident_bytes - 1`` still
    fits a minimal (8, bn) slice, so the just-below-resident budget always
    lands on ksharded rather than degrading to xla.
    """
    if tier == "resident":
        return None
    if tier == "ksharded":
        return fringe_resident_bytes(k_pad, num_rows, BN) - 1
    return 16  # xla: nothing fits


def _assert_matches_dense(rows, cols, vals, shape, n, cfg, seed=0,
                          batch=None):
    plan = spmm.prepare(rows, cols, vals, shape, cfg)
    rng = np.random.RandomState(seed + 1)
    if batch is None:
        b = rng.randn(shape[1], n).astype(np.float32)
    else:
        b = rng.randn(batch, shape[1], n).astype(np.float32)
    out = np.asarray(spmm.execute(plan, jnp.asarray(b)))
    a = np.zeros(shape, np.float64)
    if rows.size:
        np.add.at(a, (rows, cols), vals.astype(np.float64))
    expect = a @ b.astype(np.float64)
    scale = np.abs(expect).max() + 1e-9
    assert np.abs(out - expect).max() / scale < 1e-4
    return plan


# ---------------------------------------------------------------------------
# hypothesis: full pipeline under the XLA impl (splits + matrix variants)
# ---------------------------------------------------------------------------
@given(
    st.integers(0, 2**31 - 1) if HAVE_HYPOTHESIS else None,
    st.integers(1, 96) if HAVE_HYPOTHESIS else None,
    st.integers(1, 96) if HAVE_HYPOTHESIS else None,
    st.sampled_from([0.0, 0.02, 0.12, 0.5]) if HAVE_HYPOTHESIS else None,
    st.sampled_from([None, 1.0, 1e-9]) if HAVE_HYPOTHESIS else None,
    st.integers(1, 40) if HAVE_HYPOTHESIS else None,
    st.sampled_from([np.float32, np.float64]) if HAVE_HYPOTHESIS else None,
)
@settings(max_examples=16, deadline=None, derandomize=True)
def test_property_xla_pipeline_matches_dense(seed, m, k, density, alpha, n,
                                             dtype):
    """All split variants (cost-model / all-fringe / all-core) across random
    shapes and densities; density drives the matrix path across both the
    streaming and densified-GEMM occupancy branches."""
    rows, cols, vals = _random_coo(seed, m, k, density)
    cfg = spmm.SpmmConfig(impl="xla", alpha=alpha,
                          enable_col_stage=alpha is None)
    _assert_matches_dense(rows, cols, vals.astype(dtype), (m, k), n, cfg,
                          seed=seed)


# ---------------------------------------------------------------------------
# hypothesis: fringe dispatch tiers under pallas interpret mode
# ---------------------------------------------------------------------------
@given(
    st.integers(0, 2**31 - 1) if HAVE_HYPOTHESIS else None,
    st.integers(1, 40) if HAVE_HYPOTHESIS else None,
    st.integers(1, 64) if HAVE_HYPOTHESIS else None,
    st.sampled_from([0.05, 0.25]) if HAVE_HYPOTHESIS else None,
    st.sampled_from(["resident", "ksharded", "xla"]) if HAVE_HYPOTHESIS
    else None,
)
@settings(max_examples=9, deadline=None, derandomize=True)
def test_property_fringe_tiers_interpret(seed, m, k, density, tier):
    """Every fringe tier, forced by a derived VMEM budget, in interpret
    mode on an all-fringe split."""
    rows, cols, vals = _random_coo(seed, m, k, density)
    num_rows = np.unique(rows).size
    k_pad = ((k + 63) // 64) * 64
    cfg = spmm.SpmmConfig(
        impl="pallas_interpret", bn=BN, alpha=1.0,
        fringe_vmem_budget=_force_tier_budget(tier, k_pad, max(num_rows, 1)),
    )
    plan = _assert_matches_dense(rows, cols, vals, (m, k), 24, cfg, seed=seed)
    if rows.size:
        assert plan.fringe_tier == tier


# ---------------------------------------------------------------------------
# hypothesis: batched multi-RHS equals per-panel execution
# ---------------------------------------------------------------------------
@given(
    st.integers(0, 2**31 - 1) if HAVE_HYPOTHESIS else None,
    st.integers(1, 64) if HAVE_HYPOTHESIS else None,
    st.integers(1, 64) if HAVE_HYPOTHESIS else None,
    st.integers(1, 5) if HAVE_HYPOTHESIS else None,
)
@settings(max_examples=10, deadline=None, derandomize=True)
def test_property_batched_execute_matches_dense(seed, m, k, batch):
    rows, cols, vals = _random_coo(seed, m, k, 0.1)
    cfg = spmm.SpmmConfig(impl="xla")
    _assert_matches_dense(rows, cols, vals, (m, k), 16, cfg, seed=seed,
                          batch=batch)


# ---------------------------------------------------------------------------
# pinned panel: the same checker on a fixed grid (runs without hypothesis)
# ---------------------------------------------------------------------------
PINNED = [
    # (seed, m, k, density, alpha, impl, tier-or-None)
    (0, 64, 64, 0.10, None, "xla", None),
    (1, 96, 48, 0.02, 1.0, "xla", None),        # all-fringe
    (2, 96, 48, 0.50, 1e-9, "xla", None),       # all-core, densified GEMM
    (4, 40, 48, 0.15, 1.0, "pallas_interpret", "resident"),
    (5, 40, 48, 0.15, 1.0, "pallas_interpret", "ksharded"),
    (6, 40, 48, 0.15, 1.0, "pallas_interpret", "xla"),
    (7, 1, 1, 1.00, None, "xla", None),
    (8, 1, 80, 0.30, None, "xla", None),        # single row
    (9, 80, 1, 0.30, None, "xla", None),        # single col
]


@pytest.mark.parametrize("seed,m,k,density,alpha,impl,tier", PINNED)
def test_pinned_oracle_panel(seed, m, k, density, alpha, impl, tier):
    rows, cols, vals = _random_coo(seed, m, k, density)
    budget = None
    if tier is not None:
        num_rows = max(np.unique(rows).size, 1)
        k_pad = ((k + 63) // 64) * 64
        budget = _force_tier_budget(tier, k_pad, num_rows)
    cfg = spmm.SpmmConfig(impl=impl, alpha=alpha, bn=BN,
                          enable_col_stage=alpha is None,
                          fringe_vmem_budget=budget)
    plan = _assert_matches_dense(rows, cols, vals, (m, k), 24, cfg, seed=seed)
    if tier is not None and rows.size:
        assert plan.fringe_tier == tier


def _streaming_occupancy_coo():
    """All-core matrix whose block occupancy sits below the densified-GEMM
    threshold: one nonzero per row, all in k-block 0, K spanning 5 blocks —
    occupancy 1/5 < 0.25, so the XLA matrix path stays on the streaming
    tile einsum (uniform-random columns always light up every block)."""
    m, k = 300, 320
    rows = np.arange(m, dtype=np.int64)
    cols = np.zeros(m, np.int64)
    vals = np.random.RandomState(3).randn(m)
    return rows, cols, vals, (m, k)


def test_pinned_streaming_matrix_variant():
    rows, cols, vals, shape = _streaming_occupancy_coo()
    cfg = spmm.SpmmConfig(impl="xla", alpha=1e-9, enable_col_stage=False)
    _assert_matches_dense(rows, cols, vals, shape, 24, cfg, seed=3)


def test_pinned_matrix_variants_cross_occupancy_threshold():
    """The two all-core pinned cases really do land on opposite sides of
    the densified-GEMM occupancy branch (0.25 active-slot fraction)."""
    dense_plan = spmm.prepare(
        *_random_coo(2, 96, 48, 0.5), (96, 48),
        spmm.SpmmConfig(impl="xla", alpha=1e-9, enable_col_stage=False))
    rows, cols, vals, shape = _streaming_occupancy_coo()
    sparse_plan = spmm.prepare(
        rows, cols, vals, shape,
        spmm.SpmmConfig(impl="xla", alpha=1e-9, enable_col_stage=False))
    def occupancy(p):
        nkb = (p.shape[1] + p.config.bk - 1) // p.config.bk
        return p.stats_dict["num_steps"] / max(p.num_windows * nkb, 1)
    assert occupancy(dense_plan) >= 0.25
    assert occupancy(sparse_plan) < 0.25
