"""Operator family on the plan IR: SDDMM + SpGEMM + the repro.sparse facade.

Contract under test: ``sddmm`` and ``spspmm`` are *fused-body stages* of
the unified executor pipeline, not new executor families — dense numpy
parity in every dispatch flavor (forced fringe tiers, interpret-mode
pallas, batched, sharded), one jitted dispatch per call, zero extra
retraces per ``(op, signature)``, and SDDMM output feeding
``dynamic.update_values`` unchanged (the GAT round trip).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan_ir, spmm
from repro.core.cost_model import (
    FRINGE_VMEM_BUDGET, assert_vmem_claim, fringe_resident_bytes,
    sddmm_resident_bytes, select_sddmm_tier,
)
from repro.dynamic import DynamicPlan, GraphDelta, update_values
from repro.errors import PlanBuildError
from repro.exec import (
    dispatch_count, execute_sddmm, execute_spspmm, fused_trace_count,
)
from repro.launch.mesh import make_spmm_mesh
import repro.sparse as sp
from conftest import make_sparse

BN = 128  # narrow n-blocks keep interpret-mode grids small


def _force_tier_budget(tier, k_pad, num_rows):
    if tier == "resident":
        return None
    if tier == "ksharded":
        return fringe_resident_bytes(k_pad, num_rows, BN) - 1
    return 16  # xla: nothing fits


def _dense(rows, cols, vals, shape):
    a = np.zeros(shape, np.float64)
    if len(rows):
        np.add.at(a, (rows, cols), np.asarray(vals, np.float64))
    return a


def _check(out, expect, tol=1e-4):
    scale = np.abs(expect).max() + 1e-9
    assert np.abs(np.asarray(out) - expect).max() / scale < tol


def _coo(rng, m, k, nnz):
    rows = rng.randint(0, m, nnz).astype(np.int64)
    cols = rng.randint(0, k, nnz).astype(np.int64)
    return rows, cols, rng.randn(nnz)


# ---------------------------------------------------------------------------
# SDDMM vs the dense oracle, every dispatch flavor
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tier", ["resident", "ksharded", "xla"])
def test_sddmm_all_fringe_tiers_match_oracle(rng, tier):
    """Forced-budget plans (interpret-mode pallas) across all tiers.

    The tier budget also flows into the SDDMM gather tier (it is part of
    the tagged signature), so tier='xla' exercises the reference gather
    and tier='resident' the pallas lane-select kernel.
    """
    m, k, d = 72, 128, 16
    rows, cols, vals = _coo(rng, m, k, 500)
    cfg = spmm.SpmmConfig(
        impl="pallas_interpret", bn=BN, alpha=1.0,
        fringe_vmem_budget=_force_tier_budget(tier, k, m),
    )
    plan = spmm.prepare(rows, cols, vals, (m, k), cfg)
    assert plan.fringe_tier == tier
    x = rng.randn(m, d).astype(np.float32)
    y = rng.randn(d, k).astype(np.float32)
    out = execute_sddmm(plan, jnp.asarray(x), jnp.asarray(y))
    _check(out, (x.astype(np.float64) @ y.astype(np.float64))[rows, cols])


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_sddmm_mixed_core_fringe(rng, impl):
    """Default alpha: both engine paths active, dense rows in the core."""
    a, rows, cols, vals = make_sparse(rng, 96, 80, 0.07, n_dense_rows=4)
    plan = spmm.prepare(rows, cols, vals, a.shape,
                        spmm.SpmmConfig(impl=impl, bn=BN))
    d = 12
    x = rng.randn(96, d).astype(np.float32)
    y = rng.randn(d, 80).astype(np.float32)
    out = execute_sddmm(plan, jnp.asarray(x), jnp.asarray(y))
    _check(out, (x.astype(np.float64) @ y.astype(np.float64))[rows, cols])


def test_sddmm_reorder_cols(rng):
    """Column-reordered plans address Y through col_perm correctly."""
    m, k, d = 64, 96, 8
    rows, cols, vals = _coo(rng, m, k, 400)
    for impl in ("xla", "pallas_interpret"):
        plan = spmm.prepare(
            rows, cols, vals, (m, k),
            spmm.SpmmConfig(impl=impl, bn=BN, reorder_cols=True),
        )
        x = rng.randn(m, d).astype(np.float32)
        y = rng.randn(d, k).astype(np.float32)
        out = execute_sddmm(plan, jnp.asarray(x), jnp.asarray(y))
        _check(out, (x.astype(np.float64) @ y.astype(np.float64))[rows, cols])


def test_sddmm_batched_one_vmapped_dispatch(rng):
    m, k, d, batch = 80, 64, 10, 3
    rows, cols, vals = _coo(rng, m, k, 350)
    plan = spmm.prepare(rows, cols, vals, (m, k), spmm.SpmmConfig(impl="xla"))
    xb = rng.randn(batch, m, d).astype(np.float32)
    yb = rng.randn(batch, d, k).astype(np.float32)
    out = np.asarray(execute_sddmm(plan, jnp.asarray(xb), jnp.asarray(yb)))
    assert out.shape == (batch, len(rows))
    for i in range(batch):
        _check(out[i], (xb[i].astype(np.float64)
                        @ yb[i].astype(np.float64))[rows, cols])
    # mixed batching is rejected, not broadcast
    with pytest.raises(ValueError, match="batch"):
        execute_sddmm(plan, jnp.asarray(xb), jnp.asarray(yb[0]))


def test_sddmm_duplicate_coo_entries(rng):
    """Duplicate triplets share a tile slot; each gets the same dot."""
    m, k, d = 40, 32, 6
    rows = np.array([3, 3, 3, 17, 17, 39], np.int64)
    cols = np.array([5, 5, 9, 20, 20, 31], np.int64)
    vals = rng.randn(6)
    plan = spmm.prepare(rows, cols, vals, (m, k), spmm.SpmmConfig(impl="xla"))
    x = rng.randn(m, d).astype(np.float32)
    y = rng.randn(d, k).astype(np.float32)
    out = execute_sddmm(plan, jnp.asarray(x), jnp.asarray(y))
    _check(out, (x.astype(np.float64) @ y.astype(np.float64))[rows, cols])


def test_sddmm_empty_and_single_path_plans(rng):
    d = 8
    # empty pattern
    plan = spmm.prepare(np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0), (16, 24), spmm.SpmmConfig(impl="xla"))
    out = execute_sddmm(plan, jnp.ones((16, d)), jnp.ones((d, 24)))
    assert out.shape == (0,)
    # all-fringe (alpha=1) and all-core (alpha=0) plans
    rows, cols, vals = _coo(rng, 48, 40, 200)
    x = rng.randn(48, d).astype(np.float32)
    y = rng.randn(d, 40).astype(np.float32)
    expect = (x.astype(np.float64) @ y.astype(np.float64))[rows, cols]
    for alpha in (0.0, 1.0):
        plan = spmm.prepare(rows, cols, vals, (48, 40),
                            spmm.SpmmConfig(impl="xla", alpha=alpha))
        _check(execute_sddmm(plan, jnp.asarray(x), jnp.asarray(y)), expect)


def test_sddmm_operand_validation(rng):
    rows, cols, vals = _coo(rng, 32, 24, 100)
    plan = spmm.prepare(rows, cols, vals, (32, 24), spmm.SpmmConfig())
    with pytest.raises(ValueError, match="M="):
        execute_sddmm(plan, jnp.ones((31, 4)), jnp.ones((4, 24)))
    with pytest.raises(ValueError, match="K="):
        execute_sddmm(plan, jnp.ones((32, 4)), jnp.ones((4, 23)))
    with pytest.raises(ValueError, match="disagree on D"):
        execute_sddmm(plan, jnp.ones((32, 4)), jnp.ones((5, 24)))


# ---------------------------------------------------------------------------
# retrace / dispatch invariants
# ---------------------------------------------------------------------------
def test_sddmm_zero_extra_retraces(rng):
    m, k, d = 64, 48, 8
    rows, cols, vals = _coo(rng, m, k, 300)
    plan = spmm.prepare(rows, cols, vals, (m, k), spmm.SpmmConfig(impl="xla"))
    x = jnp.asarray(rng.randn(m, d).astype(np.float32))
    y = jnp.asarray(rng.randn(d, k).astype(np.float32))
    execute_sddmm(plan, x, y)  # warm
    t0, d0 = fused_trace_count(), dispatch_count()
    for _ in range(4):
        execute_sddmm(plan, x, y)
    assert fused_trace_count() - t0 == 0  # cached executor, no retrace
    assert dispatch_count() - d0 == 4     # exactly one dispatch per call
    # value-updated plan: same signature -> same executor, still no retrace
    plan2 = update_values(plan, np.arange(len(rows)), rng.randn(len(rows)))
    assert plan2.signature() == plan.signature()
    execute_sddmm(plan2, x, y)
    assert fused_trace_count() - t0 == 0


def test_sddmm_and_spmm_executors_never_alias(rng):
    """Same plan signature, different op tag -> distinct cache entries."""
    m, k = 48, 40
    rows, cols, vals = _coo(rng, m, k, 200)
    plan = spmm.prepare(rows, cols, vals, (m, k), spmm.SpmmConfig(impl="xla"))
    sig = plan.signature()
    tagged = plan_ir.tag_op(sig, "sddmm", 1, 2, 3)
    assert plan_ir.sig_op(sig) == "spmm"
    assert plan_ir.sig_op(tagged) == "sddmm"
    assert plan_ir.op_extra(tagged) == (1, 2, 3)
    assert plan_ir.untag_sig(tagged) == sig
    assert tagged != sig
    # impl helpers see through the tag (health gating + degrade path)
    assert plan_ir.sig_impl(tagged) == plan_ir.sig_impl(sig)
    fall = plan_ir.xla_fallback_sig(tagged)
    assert plan_ir.sig_impl(fall) == "xla" and plan_ir.sig_op(fall) == "sddmm"
    # spmm then sddmm on the same plan: the sddmm call must trace fresh
    b = jnp.asarray(rng.randn(k, 8).astype(np.float32))
    spmm.execute(plan, b)
    t0 = fused_trace_count()
    execute_sddmm(plan, jnp.ones((m, 4)), jnp.ones((4, k)))
    assert fused_trace_count() - t0 == 1


# ---------------------------------------------------------------------------
# SDDMM -> update_values -> SpMM (the GAT round trip)
# ---------------------------------------------------------------------------
def test_sddmm_feeds_update_values_round_trip(rng):
    m, k, d = 72, 64, 8
    rows, cols, vals = _coo(rng, m, k, 320)
    plan = spmm.prepare(rows, cols, vals, (m, k), spmm.SpmmConfig(impl="xla"))
    x = rng.randn(m, d).astype(np.float32)
    y = rng.randn(d, k).astype(np.float32)
    w = np.asarray(execute_sddmm(plan, jnp.asarray(x), jnp.asarray(y)))
    plan2 = update_values(plan, np.arange(len(rows)), w)
    b = rng.randn(k, 16).astype(np.float32)
    dense_w = np.zeros((m, k))
    np.add.at(dense_w, (rows, cols), w.astype(np.float64))
    _check(spmm.execute(plan2, jnp.asarray(b)), dense_w @ b)


# ---------------------------------------------------------------------------
# SpGEMM vs the dense oracle
# ---------------------------------------------------------------------------
def test_spspmm_matches_oracle(rng):
    m, k, n = 64, 56, 48
    ar, ac, av = _coo(rng, m, k, 300)
    br, bc, bv = _coo(rng, k, n, 250)
    pa = spmm.prepare(ar, ac, av, (m, k), spmm.SpmmConfig(impl="xla"))
    pb = spmm.prepare(br, bc, bv, (k, n), spmm.SpmmConfig(impl="xla"))
    cr, cc, cv, cshape = execute_spspmm(pa, pb)
    assert cshape == (m, n)
    ref = _dense(ar, ac, av, (m, k)) @ _dense(br, bc, bv, (k, n))
    got = np.zeros(cshape)
    got[cr, cc] = np.asarray(cv, np.float64)
    _check(got, ref)
    # row-major output, unique pattern: ready for prepare() directly
    key = cr * n + cc
    assert np.all(np.diff(key) > 0)


def test_spspmm_duplicates_accumulate_like_dense(rng):
    """Duplicate COO triplets in BOTH inputs expand independently."""
    ar = np.array([0, 0, 1, 1], np.int64)
    ac = np.array([2, 2, 3, 0], np.int64)
    av = rng.randn(4)
    br = np.array([2, 2, 3, 0, 0], np.int64)
    bc = np.array([1, 1, 4, 2, 2], np.int64)
    bv = rng.randn(5)
    pa = spmm.prepare(ar, ac, av, (2, 4), spmm.SpmmConfig())
    pb = spmm.prepare(br, bc, bv, (4, 6), spmm.SpmmConfig())
    cr, cc, cv, cshape = execute_spspmm(pa, pb)
    ref = _dense(ar, ac, av, (2, 4)) @ _dense(br, bc, bv, (4, 6))
    got = np.zeros(cshape)
    got[cr, cc] = np.asarray(cv, np.float64)
    _check(got, ref)


def test_spspmm_empty_and_disjoint(rng):
    empty = spmm.prepare(np.zeros(0, np.int64), np.zeros(0, np.int64),
                         np.zeros(0), (8, 8), spmm.SpmmConfig())
    pa = spmm.prepare(np.array([0]), np.array([1]), np.array([2.0]),
                      (8, 8), spmm.SpmmConfig())
    for a, b in ((empty, pa), (pa, empty)):
        cr, cc, cv, cshape = execute_spspmm(a, b)
        assert cr.size == 0 and cc.size == 0 and cv.shape == (0,)
    # structurally disjoint: A's columns never meet a B row
    pb = spmm.prepare(np.array([5]), np.array([3]), np.array([1.0]),
                      (8, 8), spmm.SpmmConfig())
    cr, cc, cv, _ = execute_spspmm(pa, pb)
    assert cr.size == 0
    with pytest.raises(ValueError, match="inner"):
        execute_spspmm(pa, spmm.prepare(np.array([0]), np.array([0]),
                                        np.array([1.0]), (9, 4),
                                        spmm.SpmmConfig()))


def test_spspmm_one_dispatch_zero_retrace(rng):
    m, k, n = 48, 40, 32
    ar, ac, av = _coo(rng, m, k, 200)
    br, bc, bv = _coo(rng, k, n, 180)
    pa = spmm.prepare(ar, ac, av, (m, k), spmm.SpmmConfig())
    pb = spmm.prepare(br, bc, bv, (k, n), spmm.SpmmConfig())
    execute_spspmm(pa, pb)  # warm
    t0, d0 = fused_trace_count(), dispatch_count()
    execute_spspmm(pa, pb)
    execute_spspmm(pa, pb)
    assert fused_trace_count() - t0 == 0
    assert dispatch_count() - d0 == 2


# ---------------------------------------------------------------------------
# sharded flavors (1-way in-process; 2/4-way in the forced-mesh worker)
# ---------------------------------------------------------------------------
def test_sddmm_sharded_matches_single_device(rng):
    m, k, d = 96, 64, 12
    rows, cols, vals = _coo(rng, m, k, 400)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, (m, k), cfg)
    splan = spmm.prepare_sharded(rows, cols, vals, (m, k),
                                 make_spmm_mesh(1), cfg)
    x = jnp.asarray(rng.randn(m, d).astype(np.float32))
    y = jnp.asarray(rng.randn(d, k).astype(np.float32))
    ref = np.asarray(execute_sddmm(plan, x, y))
    np.testing.assert_allclose(np.asarray(execute_sddmm(splan, x, y)),
                               ref, rtol=1e-5, atol=1e-5)
    xb = jnp.asarray(rng.randn(2, m, d).astype(np.float32))
    yb = jnp.asarray(rng.randn(2, d, k).astype(np.float32))
    refb = np.asarray(execute_sddmm(plan, xb, yb))
    np.testing.assert_allclose(np.asarray(execute_sddmm(splan, xb, yb)),
                               refb, rtol=1e-5, atol=1e-5)


def test_spspmm_sharded_inputs(rng):
    m, k, n = 80, 64, 48
    ar, ac, av = _coo(rng, m, k, 300)
    br, bc, bv = _coo(rng, k, n, 250)
    cfg = spmm.SpmmConfig(impl="xla")
    sa = spmm.prepare_sharded(ar, ac, av, (m, k), make_spmm_mesh(1), cfg)
    pb = spmm.prepare(br, bc, bv, (k, n), cfg)
    cr, cc, cv, cshape = execute_spspmm(sa, pb)
    ref = _dense(ar, ac, av, (m, k)) @ _dense(br, bc, bv, (k, n))
    got = np.zeros(cshape)
    got[cr, cc] = np.asarray(cv, np.float64)
    _check(got, ref)


def test_forced_mesh_operator_family(forced_mesh_run):
    """2/4-way sharded SDDMM + spspmm parity in a forced-device subprocess."""
    import os

    worker = os.path.join(os.path.dirname(__file__),
                          "_operator_family_worker.py")
    out = forced_mesh_run(worker, n_devices=8)
    assert "OPERATORS OK" in out.stdout


# ---------------------------------------------------------------------------
# cost model: sddmm tier + the consolidated VMEM claim helper
# ---------------------------------------------------------------------------
def test_select_sddmm_tier_budget_boundary():
    d, ns, nd = 64, 512, 512
    need = sddmm_resident_bytes(d, ns, nd)
    assert select_sddmm_tier(d, ns, nd, vmem_budget=need) == "resident"
    assert select_sddmm_tier(d, ns, nd, vmem_budget=need - 1) == "xla"
    assert select_sddmm_tier(16, 64, 64) == "resident"  # default budget


def test_assert_vmem_claim():
    assert_vmem_claim(FRINGE_VMEM_BUDGET, "fits")  # no raise
    with pytest.raises(ValueError, match="VMEM"):
        assert_vmem_claim(2**31, "too big")


# ---------------------------------------------------------------------------
# the repro.sparse facade
# ---------------------------------------------------------------------------
def test_facade_surface(rng):
    m, k, n, d = 48, 40, 24, 8
    rows, cols, vals = _coo(rng, m, k, 200)
    A = sp.from_coo(rows, cols, vals, (m, k), impl="xla")
    assert A.shape == (m, k) and A.nnz == 200 and not A.is_dynamic
    dense = _dense(rows, cols, vals, (m, k))
    np.testing.assert_allclose(A.dense(), dense)
    b = rng.randn(k, n).astype(np.float32)
    _check(sp.spmm(A, b), dense @ b)
    _check(A @ b, dense @ b)
    b3 = rng.randn(2, k, n).astype(np.float32)
    out = np.asarray(sp.bspmm(A, b3))
    for i in range(2):
        _check(out[i], dense @ b3[i])
    with pytest.raises(ValueError, match="batch"):
        sp.bspmm(A, b)
    x = rng.randn(m, d).astype(np.float32)
    y = rng.randn(d, k).astype(np.float32)
    w = sp.sddmm(A, x, y, deadline=60.0)
    _check(w, (x.astype(np.float64) @ y.astype(np.float64))[rows, cols])
    # with_values: functional, same executor, new values
    A2 = A.with_values(np.asarray(w))
    np.testing.assert_allclose(A2.dense(),
                               _dense(rows, cols, np.asarray(w), (m, k)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(A.dense(), dense)  # original untouched
    with pytest.raises(ValueError, match="nonzero"):
        A.with_values(np.ones(3))
    # spspmm returns a prepared SparseMatrix (operator sugar: A @ B)
    br, bc, bv = _coo(rng, k, n, 150)
    B = sp.from_coo(br, bc, bv, (k, n))
    C = A @ B
    assert isinstance(C, sp.SparseMatrix) and C.shape == (m, n)
    _check(C.dense(), dense @ _dense(br, bc, bv, (k, n)))


def test_facade_config_handling(rng):
    rows, cols, vals = _coo(rng, 32, 24, 80)
    cfg = spmm.SpmmConfig(impl="xla", bn=BN)
    A = sp.from_coo(rows, cols, vals, (32, 24), config=cfg)
    assert A.plan.config.bn == BN
    B = sp.from_coo(rows, cols, vals, (32, 24), alpha=1.0)
    assert B.plan.config.alpha == 1.0
    with pytest.raises(ValueError, match="not both"):
        sp.from_coo(rows, cols, vals, (32, 24), config=cfg, bn=64)
    with pytest.raises(TypeError):
        sp.spmm(np.ones((3, 3)), np.ones((3, 2)))


def test_facade_dynamic_flavor(rng):
    m, k, d = 56, 48, 8
    rows, cols, vals = _coo(rng, m, k, 250)
    A = sp.from_coo(rows, cols, vals, (m, k), dynamic=True)
    assert A.is_dynamic
    x = rng.randn(m, d).astype(np.float32)
    y = rng.randn(d, k).astype(np.float32)
    expect = (x.astype(np.float64) @ y.astype(np.float64))[rows, cols]
    _check(sp.sddmm(A, x, y), expect)
    # pending structural deltas invalidate the prepared pattern
    dense = _dense(rows, cols, vals, (m, k))
    zr, zc = np.nonzero(dense == 0)
    A.plan.update(GraphDelta(ins_rows=zr[:2], ins_cols=zc[:2],
                             ins_vals=np.ones(2)))
    with pytest.raises(PlanBuildError, match="compact"):
        sp.sddmm(A, x, y)
    A.plan.compact()
    out = sp.sddmm(A, x, y)
    rows2, cols2, _ = A.coo()
    _check(out, (x.astype(np.float64)
                 @ y.astype(np.float64))[rows2, cols2])


def test_facade_deadline(rng):
    from repro.errors import DeadlineExceeded

    rows, cols, vals = _coo(rng, 32, 24, 80)
    A = sp.from_coo(rows, cols, vals, (32, 24))
    b = rng.randn(24, 8).astype(np.float32)
    with pytest.raises(DeadlineExceeded):
        sp.spmm(A, b, deadline=0.0)
    _check(sp.spmm(A, b, deadline=120.0),
           _dense(rows, cols, vals, (32, 24)) @ b)


def test_core_spmm_forwarders_deprecated_once():
    import warnings

    import repro.core.spmm as core_spmm

    core_spmm._WARNED_FORWARD = False
    with pytest.warns(DeprecationWarning, match="repro.sparse"):
        core_spmm.__getattr__("execute")
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second access stays silent
        assert core_spmm.__getattr__("execute") is not None
        assert core_spmm.__getattr__("dispatch_count") is not None
