"""Telemetry end-to-end: zero-cost guarantee, roofline, service tracing.

The contract under test (ISSUE 9): turning ``SpmmConfig.telemetry`` on is
host-side only — bit-identical numeric output, zero plan-signature
changes, zero extra retraces, zero extra device dispatches — while the
``repro.obs`` snapshot gains per-request traces and the matrix-path vs
fringe-path roofline attribution.  Also pins the legacy counter surfaces
(``SpmmService.health()`` schema, the ``fused_trace_count`` /
``dispatch_count`` / ``prepare_call_count`` hooks) that now ride on the
shared registry, and regression-tests the health-table snapshot/reset
race the migration fixed.
"""
import dataclasses
import threading

import numpy as np
import pytest

import repro.obs as obs
import repro.sparse as sp
from repro.core import spmm
from repro.exec.health import HealthTable
from repro.obs import PROFILER, TRACES, parse_prometheus_text
from repro.serve import SpmmService
from conftest import make_sparse


def _counter_clock(step=0.001):
    state = {"t": 0.0}
    lock = threading.Lock()

    def clock():
        with lock:
            state["t"] += step
            return state["t"]

    return clock


def _prepare_pair(rng, m=96, k=80, **overrides):
    """The same matrix prepared with telemetry off and on."""
    a, rows, cols, vals = make_sparse(rng, m, k, 0.08, n_dense_rows=3)
    cfg_off = spmm.SpmmConfig(impl="xla", **overrides)
    cfg_on = dataclasses.replace(cfg_off, telemetry=True)
    p_off = spmm.prepare(rows, cols, vals, a.shape, config=cfg_off)
    p_on = spmm.prepare(rows, cols, vals, a.shape, config=cfg_on)
    return a, p_off, p_on


# ---------------------------------------------------------------------------
# the zero-cost guarantee
# ---------------------------------------------------------------------------


def test_telemetry_is_signature_invisible(rng):
    _, p_off, p_on = _prepare_pair(rng)
    assert p_off.signature() == p_on.signature()


def test_telemetry_bit_identical_no_extra_traces_or_dispatches(rng):
    a, p_off, p_on = _prepare_pair(rng)
    b = rng.randn(a.shape[1], 16).astype(np.float32)
    # warm both paths: same signature -> one shared cached executor, so
    # the steady-state deltas below measure exactly one dispatch each
    spmm.execute(p_off, b)
    traces0 = spmm.fused_trace_count()
    disp0 = spmm.dispatch_count()
    out_off = np.asarray(spmm.execute(p_off, b))
    traces_off = spmm.fused_trace_count() - traces0
    disp_off = spmm.dispatch_count() - disp0

    traces0 = spmm.fused_trace_count()
    disp0 = spmm.dispatch_count()
    out_on = np.asarray(spmm.execute(p_on, b))
    traces_on = spmm.fused_trace_count() - traces0
    disp_on = spmm.dispatch_count() - disp0

    np.testing.assert_array_equal(out_off, out_on)  # bit-identical
    assert traces_off == traces_on == 0  # zero extra retraces
    assert disp_off == disp_on == 1  # zero extra device dispatches


def test_telemetry_off_records_nothing(rng):
    a, p_off, _ = _prepare_pair(rng)
    b = rng.randn(a.shape[1], 8).astype(np.float32)
    PROFILER.reset()
    spmm.execute(p_off, b)
    assert len(PROFILER) == 0


# ---------------------------------------------------------------------------
# roofline attribution
# ---------------------------------------------------------------------------


def test_roofline_snapshot_for_profiled_run(rng):
    # unique shape -> fresh signature -> the first call really traces;
    # alpha=0.5 routes the sparse tail onto the fringe (vector) path so
    # both engines carry modeled work
    a, _, p_on = _prepare_pair(rng, m=97, k=83, alpha=0.5)
    b = rng.randn(a.shape[1], 16).astype(np.float32)
    PROFILER.reset()
    spmm.execute(p_on, b)  # first call traces -> excluded from the report
    for _ in range(3):
        spmm.execute(p_on, b)

    snap = obs.snapshot()
    attr = snap["roofline"]
    assert attr["skipped_traced"] >= 1
    (row,) = attr["rows"]
    assert row["op"] == "spmm" and row["tier"] == "xla"
    assert row["calls"] == 3
    assert row["measured_us"] > 0
    # the prepared matrix has dense rows and a sparse tail, so both engine
    # paths carry modeled work and the attribution splits the wall clock
    assert row["paths"]["matrix"]["flops"] > 0
    assert row["paths"]["fringe"]["flops"] > 0
    shares = [row["paths"][p]["share"] for p in ("matrix", "fringe")]
    assert sum(shares) == pytest.approx(1.0)
    attributed = (attr["matrix_path"]["attributed_us"]
                  + attr["fringe_path"]["attributed_us"])
    assert attributed == pytest.approx(attr["measured_us_total"])

    # Prometheus export round-trips the same numbers
    parsed = parse_prometheus_text(obs.prometheus_text())
    key = (("op", "spmm"), ("sig", row["sig"]), ("tier", "xla"))
    assert parsed["repro_roofline_calls"][key] == 3.0
    assert parsed["repro_roofline_measured_us"][key] == pytest.approx(
        row["measured_us"])


def test_sddmm_and_spspmm_profiled(rng):
    a, rows, cols, vals = make_sparse(rng, 48, 48, 0.1)  # square: A @ A
    A = sp.from_coo(rows, cols, vals, a.shape, impl="xla", telemetry=True)
    x = rng.randn(48, 8).astype(np.float32)
    y = rng.randn(8, 48).astype(np.float32)
    PROFILER.reset()
    sp.sddmm(A, x, y)
    sp.spspmm(A, A.with_values(np.abs(vals)))
    ops = {r.op for r in PROFILER.records()}
    assert "sddmm" in ops and "spspmm" in ops


# ---------------------------------------------------------------------------
# facade + service tracing
# ---------------------------------------------------------------------------


def test_facade_trace_spans(rng):
    a, rows, cols, vals = make_sparse(rng, 64, 48, 0.1)
    A = sp.from_coo(rows, cols, vals, a.shape, impl="xla", telemetry=True)
    b = rng.randn(48, 8).astype(np.float32)
    TRACES.reset()
    out = sp.spmm(A, b)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-4, atol=1e-4)
    (tr,) = TRACES.snapshot()
    assert tr["name"] == "facade:spmm"
    assert tr["attrs"]["outcome"] == "ok"
    assert [s["name"] for s in tr["spans"]] == ["dispatch"]


def test_facade_without_telemetry_traces_nothing(rng):
    a, rows, cols, vals = make_sparse(rng, 64, 48, 0.1)
    A = sp.from_coo(rows, cols, vals, a.shape, impl="xla")
    TRACES.reset()
    sp.spmm(A, rng.randn(48, 8).astype(np.float32))
    assert len(TRACES) == 0


def test_service_span_structure_pinned(rng):
    """An injected deterministic clock pins the traced request's spans."""
    cfg = spmm.SpmmConfig(impl="xla", telemetry=True)
    svc = SpmmService(cfg, max_batch=4)
    svc._clock = _counter_clock()
    a, rows, cols, vals = make_sparse(rng, 90, 70, 0.08)
    svc.register("g", rows, cols, vals, a.shape)
    TRACES.reset()
    b = rng.randn(70, 8).astype(np.float32)
    ticket = svc.submit("g", b)
    svc.flush()
    out = np.asarray(svc.fetch(ticket))
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)

    (tr,) = TRACES.snapshot()
    assert tr["name"] == "spmm:g"
    assert tr["attrs"]["ticket"] == ticket
    assert tr["attrs"]["outcome"] == "ok"
    assert [s["name"] for s in tr["spans"]] == [
        "admit", "queue_wait", "batch_assembly", "dispatch",
        "block_until_ready", "fetch",
    ]
    # the counter clock ticks monotonically, so the spans chain in order
    for s in tr["spans"]:
        assert s["end_us"] >= s["start_us"]
    assert tr["end_us"] >= tr["start_us"]
    assert tr["spans"][2]["attrs"] == {"batch": 1, "bucket": 1}


def test_service_failure_outcomes_traced(rng):
    cfg = spmm.SpmmConfig(impl="xla", telemetry=True)
    svc = SpmmService(cfg, max_batch=2, max_queue=1,
                      admission_policy="shed-oldest")
    svc._clock = _counter_clock()
    a, rows, cols, vals = make_sparse(rng, 90, 70, 0.08)
    svc.register("g", rows, cols, vals, a.shape)
    TRACES.reset()
    b = rng.randn(70, 8).astype(np.float32)
    t_shed = svc.submit("g", b)
    svc.submit("g", b, timeout=1e-9)  # expires before the drain
    svc.flush()
    outcomes = {t["attrs"]["ticket"]: t["attrs"]["outcome"]
                for t in TRACES.snapshot()}
    assert outcomes[t_shed] == "shed"
    assert "expired" in outcomes.values()


def test_untraced_service_output_matches_traced(rng):
    a, rows, cols, vals = make_sparse(rng, 90, 70, 0.08)
    b = rng.randn(70, 8).astype(np.float32)
    outs = []
    for telemetry in (False, True):
        cfg = spmm.SpmmConfig(impl="xla", telemetry=telemetry)
        svc = SpmmService(cfg, max_batch=4)
        svc.register("g", rows, cols, vals, a.shape)
        t = svc.submit("g", b)
        svc.flush()
        outs.append(np.asarray(svc.fetch(t)))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# legacy counter surfaces on the shared registry
# ---------------------------------------------------------------------------

_LEGACY_STATS_KEYS = {
    "requests", "flushes", "dispatches", "padded_slots", "updates",
    "warm_starts", "compactions_scheduled", "compactions_applied",
    "compactions_stale", "compactions_failed", "admission_rejected",
    "admission_shed", "deadline_expired", "quarantines",
    "tunings_scheduled", "tunings_applied", "tunings_failed",
    # executor health table, folded in with the executor_ prefix
    "executor_signatures", "executor_demoted", "executor_retrying",
    "executor_failures", "executor_fallbacks", "executor_demotions",
    "executor_recoveries",
    "faults_fired",
    # autotuner counters, folded in with the tuner_ prefix
    "tuner_tune_calls", "tuner_table_hits", "tuner_cold_misses",
    "tuner_measured", "tuner_store_errors", "tuner_records",
}


def test_health_schema_byte_compatible(rng):
    """The registry migration must not change a single health() key."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=2)
    a, rows, cols, vals = make_sparse(rng, 90, 70, 0.08)
    svc.register("g", rows, cols, vals, a.shape)
    t = svc.submit("g", rng.randn(70, 8).astype(np.float32))
    svc.flush()
    svc.fetch(t)
    h = svc.health()
    assert set(h) == {"closed", "matrices", "stats"}
    assert set(h["matrices"]["g"]) == {
        "state", "queue_depth", "fold_failures", "fold_in_flight"}
    assert set(h["stats"]) == _LEGACY_STATS_KEYS
    assert h["stats"]["requests"] == 1
    assert h["stats"]["dispatches"] == 1
    assert h["stats"]["flushes"] == 1


def test_hook_wrappers_still_count(rng):
    a, rows, cols, vals = make_sparse(rng, 64, 48, 0.1)
    p0 = spmm.prepare_call_count()
    cfg = spmm.SpmmConfig(impl="xla", bn=32)  # distinct sig: fresh trace
    plan = spmm.prepare(rows, cols, vals, a.shape, config=cfg)
    assert spmm.prepare_call_count() == p0 + 1
    b = np.random.RandomState(1).randn(48, 8).astype(np.float32)
    t0, d0 = spmm.fused_trace_count(), spmm.dispatch_count()
    spmm.execute(plan, b)
    spmm.execute(plan, b)
    assert spmm.fused_trace_count() == t0 + 1  # traced once, reused once
    assert spmm.dispatch_count() == d0 + 2
    # the hooks are views over the shared registry
    reg = obs.REGISTRY
    assert reg.get("exec_traces_total").value(kind="fused") == (
        spmm.fused_trace_count())
    assert reg.get("exec_dispatches_total").total() == spmm.dispatch_count()
    assert reg.get("core_prepares_total").total() == (
        spmm.prepare_call_count())


# ---------------------------------------------------------------------------
# concurrency: the registry under threaded load + the snapshot/reset race
# ---------------------------------------------------------------------------


def test_registry_survives_concurrent_services(rng):
    """Several services submit/flush/fetch in parallel; every per-instance
    stat stays exact even though all series live in one registry."""
    a, rows, cols, vals = make_sparse(rng, 64, 48, 0.1)
    n_services, n_requests = 4, 6
    services = []
    for _ in range(n_services):
        svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
        svc.register("g", rows, cols, vals, a.shape)
        services.append(svc)
    b = rng.randn(48, 8).astype(np.float32)
    errors = []

    def drive(svc):
        try:
            for _ in range(n_requests):
                t = svc.submit("g", b)
                svc.flush()
                np.asarray(svc.fetch(t))
        except BaseException as err:  # surfaced after join
            errors.append(err)

    threads = [threading.Thread(target=drive, args=(s,)) for s in services]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    for svc in services:
        assert svc.stats.requests == n_requests
        assert svc.stats.flushes == n_requests
        assert svc.stats.dispatches == n_requests


def test_health_table_snapshot_reset_race():
    """Regression: snapshot()/reset() used to read counters outside the
    table lock, so a concurrent record_* could be half-visible.

    With ``max_retries=0`` the first failure of a fresh signature bumps
    the failure *and* demotion counters inside one lock acquisition, and
    marks the signature demoted in the same critical section — so every
    atomic snapshot must observe ``failures == demotions == demoted``.
    ``reset()`` clears signatures and counters together, preserving the
    invariant; the pre-fix code could tear any of the three apart.
    """
    table = HealthTable(max_retries=0)
    n_threads, n_iter = 4, 200
    stop = threading.Event()
    torn = []

    def record(tid):
        for i in range(n_iter):
            table.record_failure((tid, i), RuntimeError("x"))

    def observe():
        while not stop.is_set():
            snap = table.snapshot()
            if not (snap["failures"] == snap["demotions"]
                    == snap["demoted"]):
                torn.append(snap)
            table.reset()

    workers = [threading.Thread(target=record, args=(t,))
               for t in range(n_threads)]
    watcher = threading.Thread(target=observe)
    watcher.start()
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    stop.set()
    watcher.join()
    assert not torn, f"torn snapshots: {torn[:3]}"
    table.reset()
    snap = table.snapshot()
    assert snap["failures"] == 0 and snap["demotions"] == 0
