"""Sharded checkpointing: round trip, atomicity, retention, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {
        "a": {"w": jnp.asarray(r.randn(10, 6).astype(np.float32))},
        "b": [jnp.asarray(r.randn(4).astype(np.float32)),
              jnp.asarray(np.int32(7))],
    }


def test_round_trip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 5, t, meta={"note": "x"})
    step, restored = ck.restore(str(tmp_path), t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_files_created(tmp_path):
    t = {"w": jnp.zeros((10, 4))}
    d = ck.save(str(tmp_path), 1, t, num_shards=3)
    files = [f for f in os.listdir(d) if f.startswith("w.s")]
    assert len(files) == 3


def test_latest_and_retention(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t, keep=3)
    assert ck.latest_step(str(tmp_path)) == 5
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(str(tmp_path)))
    assert steps == [3, 4, 5]


def test_no_tmp_left_behind(tmp_path):
    ck.save(str(tmp_path), 9, _tree())
    assert not [d for d in os.listdir(str(tmp_path)) if d.startswith(".tmp")]


def test_restore_specific_step(tmp_path):
    t1, t2 = _tree(1), _tree(2)
    ck.save(str(tmp_path), 1, t1, keep=5)
    ck.save(str(tmp_path), 2, t2, keep=5)
    step, restored = ck.restore(str(tmp_path), t1, step=1)
    np.testing.assert_array_equal(
        np.asarray(restored["a"]["w"]), np.asarray(t1["a"]["w"]))


def test_shape_mismatch_rejected(tmp_path):
    ck.save(str(tmp_path), 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(AssertionError):
        ck.restore(str(tmp_path), {"w": jnp.zeros((5, 4))})


def test_elastic_restore_resharded(tmp_path):
    """Restore under new shardings (single-device: SingleDeviceSharding)."""
    t = _tree()
    ck.save(str(tmp_path), 3, t)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    step, restored = ck.restore_resharded(str(tmp_path), t, shardings)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
