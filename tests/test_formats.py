"""Format round-trips + tile-redundancy metric (paper Table 1)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import formats
from conftest import make_sparse


def test_coo_round_trip(rng):
    a, rows, cols, vals = make_sparse(rng, 50, 40, 0.1)
    coo = formats.coo_from_dense(a)
    assert coo.nnz == len(rows)
    np.testing.assert_allclose(formats.dense_from_coo(coo), a)


def test_coo_row_sorted(rng):
    a, *_ = make_sparse(rng, 30, 30, 0.2)
    coo = formats.coo_from_dense(a)
    r = np.asarray(coo.rows)
    assert (np.diff(r) >= 0).all()


@pytest.mark.parametrize("bm,bk", [(8, 8), (16, 32), (128, 64)])
def test_block_ell_round_trip(rng, bm, bk):
    a, rows, cols, vals = make_sparse(rng, 70, 90, 0.08)
    be = formats.block_ell_from_coo(rows, cols, vals, a.shape, bm, bk)
    np.testing.assert_allclose(formats.dense_from_block_ell(be), a, rtol=1e-6)


def test_block_ell_row_permutation(rng):
    a, rows, cols, vals = make_sparse(rng, 40, 40, 0.1)
    order = np.random.RandomState(1).permutation(40)
    be = formats.block_ell_from_coo(rows, cols, vals, a.shape, 8, 8,
                                    row_order=order)
    np.testing.assert_allclose(formats.dense_from_block_ell(be), a, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(5, 60), k=st.integers(5, 60),
    density=st.floats(0.01, 0.4), seed=st.integers(0, 99),
)
def test_block_ell_nnz_conserved(m, k, density, seed):
    """Property: packing stores every nonzero exactly once."""
    r = np.random.RandomState(seed)
    a = (r.rand(m, k) < density) * r.randn(m, k)
    rows, cols = np.nonzero(a)
    vals = a[rows, cols]
    be = formats.block_ell_from_coo(rows, cols, vals, (m, k), 8, 8)
    assert be.nnz == len(rows)
    dense = formats.dense_from_block_ell(be)
    np.testing.assert_allclose(dense, a, rtol=1e-6, atol=1e-8)


def test_active_tile_zero_fraction_trend(rng):
    """Paper Table 1: redundancy grows with tile size."""
    a, rows, cols, _ = make_sparse(rng, 512, 512, 0.01)
    fracs = [
        formats.active_tile_zero_fraction(rows, cols, a.shape, t)
        for t in (4, 16, 32, 64, 128)
    ]
    assert all(b >= a - 1e-9 for a, b in zip(fracs, fracs[1:])), fracs
    assert fracs[-1] > fracs[0]


def test_empty_matrix():
    be = formats.block_ell_from_coo(
        np.zeros(0, np.int64), np.zeros(0, np.int64),
        np.zeros(0, np.float32), (0, 16), 8, 8,
    )
    assert be.num_windows == 0
    assert be.nnz == 0
