"""Differential parity + invariants for the unified executor pipeline.

The refactor contract: every executor flavor the old five hand-rolled
factories produced — fused, batched, all three fringe dispatch tiers,
delta-extended, sharded rows/rhs, sharded+delta — now comes out of one
``exec.pipeline.build_executor`` and must (a) match the fp64 dense oracle,
(b) introduce zero extra retraces over the pre-refactor cache behavior,
and (c) execute sharded dynamic plans as a single dispatch with bit-parity
to the legacy two-dispatch post-pass.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan_ir, spmm
from repro.core.cost_model import fringe_resident_bytes
from repro.dynamic import DynamicPlan, GraphDelta, build_delta_fringe
from repro.exec import (
    EXECUTOR_CACHE, build_executor, dispatch_count, fused_trace_count,
    sharded_trace_count,
)
from repro.launch.mesh import make_spmm_mesh
from conftest import make_sparse

BN = 128  # narrow n-blocks keep interpret-mode grids small


def _force_tier_budget(tier, k_pad, num_rows):
    if tier == "resident":
        return None
    if tier == "ksharded":
        return fringe_resident_bytes(k_pad, num_rows, BN) - 1
    return 16  # xla: nothing fits


def _dense(rows, cols, vals, shape):
    a = np.zeros(shape, np.float64)
    if len(rows):
        np.add.at(a, (rows, cols), np.asarray(vals, np.float64))
    return a


def _check(out, expect):
    scale = np.abs(expect).max() + 1e-9
    assert np.abs(np.asarray(out) - expect).max() / scale < 1e-4


# ---------------------------------------------------------------------------
# differential parity: every flavor against the dense oracle
# ---------------------------------------------------------------------------
def test_fused_and_batched_flavors_match_oracle(rng):
    a, rows, cols, vals = make_sparse(rng, 96, 80, 0.07, n_dense_rows=4)
    plan = spmm.prepare(rows, cols, vals, a.shape, spmm.SpmmConfig(impl="xla"))
    dense = _dense(rows, cols, vals, a.shape)
    b = rng.randn(80, 16).astype(np.float32)
    _check(spmm.execute(plan, jnp.asarray(b)), dense @ b)
    b3 = rng.randn(3, 80, 16).astype(np.float32)
    out = np.asarray(spmm.execute(plan, jnp.asarray(b3)))
    for i in range(3):
        _check(out[i], dense @ b3[i])


@pytest.mark.parametrize("tier", ["resident", "ksharded", "xla"])
def test_fringe_tiers_match_oracle(rng, tier):
    """All three vector-path dispatch tiers through the unified builder,
    forced by derived VMEM budgets, in interpret mode."""
    m, k = 72, 128
    nnz = 500
    rows = rng.randint(0, m, nnz).astype(np.int64)
    cols = rng.randint(0, k, nnz).astype(np.int64)
    vals = rng.randn(nnz)
    cfg = spmm.SpmmConfig(
        impl="pallas_interpret", bn=BN, alpha=1.0,
        fringe_vmem_budget=_force_tier_budget(tier, k, m),
    )
    plan = spmm.prepare(rows, cols, vals, (m, k), cfg)
    if rows.size:
        assert plan.fringe_tier == tier
    b = rng.randn(k, 32).astype(np.float32)
    _check(spmm.execute(plan, jnp.asarray(b)),
           _dense(rows, cols, vals, (m, k)) @ b)


def test_delta_flavors_match_oracle(rng):
    a, rows, cols, vals = make_sparse(rng, 80, 64, 0.06, n_dense_rows=3)
    plan = spmm.prepare(rows, cols, vals, a.shape, spmm.SpmmConfig(impl="xla"))
    dense = _dense(rows, cols, vals, a.shape)
    zr, zc = np.nonzero(dense == 0)
    pick = rng.choice(zr.size, 12, replace=False)
    dv = rng.randn(12)
    delta = build_delta_fringe(zr[pick], zc[pick], dv, a.shape, plan.config)
    dense[zr[pick], zc[pick]] += dv
    b = rng.randn(64, 8).astype(np.float32)
    _check(spmm.execute_with_delta(plan, delta, jnp.asarray(b)), dense @ b)
    b3 = rng.randn(2, 64, 8).astype(np.float32)
    out = np.asarray(spmm.execute_with_delta(plan, delta, jnp.asarray(b3)))
    for i in range(2):
        _check(out[i], dense @ b3[i])
    # the standalone contribution (legacy post-pass term) is the difference
    contrib = spmm.execute_delta_contribution(
        a.shape, plan.config, delta, jnp.asarray(b)
    )
    _check(np.asarray(spmm.execute(plan, jnp.asarray(b))) + contrib,
           dense @ b)


@pytest.mark.parametrize("shard_axis", ["rows", "rhs"])
def test_sharded_flavors_match_oracle(rng, shard_axis):
    a, rows, cols, vals = make_sparse(rng, 96, 64, 0.07, n_dense_rows=4)
    mesh = make_spmm_mesh(1)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh,
                                 spmm.SpmmConfig(impl="xla"),
                                 shard_axis=shard_axis)
    dense = _dense(rows, cols, vals, a.shape)
    b = rng.randn(64, 16).astype(np.float32)
    _check(spmm.execute_sharded(splan, jnp.asarray(b)), dense @ b)
    b3 = rng.randn(2, 64, 16).astype(np.float32)
    out = np.asarray(spmm.execute_sharded(splan, jnp.asarray(b3)))
    for i in range(2):
        _check(out[i], dense @ b3[i])


@pytest.mark.parametrize("shard_axis", ["rows", "rhs"])
def test_sharded_delta_matches_oracle(rng, shard_axis):
    """Sharded + structural delta through the in-body merge, both axes."""
    a, rows, cols, vals = make_sparse(rng, 96, 64, 0.07, n_dense_rows=4)
    mesh = make_spmm_mesh(1)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh,
                                 spmm.SpmmConfig(impl="xla"),
                                 shard_axis=shard_axis)
    dp = DynamicPlan(splan, auto_compact=False)
    dense = _dense(rows, cols, vals, a.shape)
    zr, zc = np.nonzero(dense == 0)
    pick = rng.choice(zr.size, 10, replace=False)
    iv = rng.randn(10)
    dp.update(GraphDelta.inserts(zr[pick], zc[pick], iv))
    dense[zr[pick], zc[pick]] += iv
    dpick = rng.choice(rows.size, 5, replace=False)
    dp.update(GraphDelta.deletes(rows[dpick], cols[dpick]))
    dense[rows[dpick], cols[dpick]] = 0.0
    b = rng.randn(64, 16).astype(np.float32)
    _check(dp.execute(jnp.asarray(b)), dense @ b)


# ---------------------------------------------------------------------------
# single dispatch + bit-parity for the sharded delta merge
# ---------------------------------------------------------------------------
def test_sharded_delta_is_one_dispatch_with_bit_parity(rng):
    """The routed sidecar merges inside the shard_map program: exactly one
    executor dispatch, bit-identical to the legacy two-dispatch post-pass
    (execute_sharded + execute_delta_contribution).  The 2/4-way version of
    this check runs in tests/_dynamic_sharded_worker.py."""
    a, rows, cols, vals = make_sparse(rng, 96, 64, 0.07, n_dense_rows=4)
    cfg = spmm.SpmmConfig(impl="xla")
    mesh = make_spmm_mesh(1)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh, cfg,
                                 shard_axis="rows")
    dp = DynamicPlan(splan, auto_compact=False)
    dense = _dense(rows, cols, vals, a.shape)
    zr, zc = np.nonzero(dense == 0)
    pick = rng.choice(zr.size, 9, replace=False)
    iv = rng.randn(9)
    dp.update(GraphDelta.inserts(zr[pick], zc[pick], iv))
    b = jnp.asarray(rng.randn(64, 16).astype(np.float32))

    delta = dp._materialize()
    assert isinstance(delta, plan_ir.ShardedDeltaFringe)
    dp.execute(b)  # warm the executor so the counted call is steady-state
    before = dispatch_count()
    fused = np.asarray(dp.execute(b))
    assert dispatch_count() - before == 1

    plain = build_delta_fringe(zr[pick], zc[pick], iv, a.shape, cfg)
    legacy = np.asarray(spmm.execute_sharded(splan, b)) + np.asarray(
        spmm.execute_delta_contribution(a.shape, cfg, plain, b)
    )
    assert np.array_equal(fused, legacy)


# ---------------------------------------------------------------------------
# trace-count invariants: the unified builder never adds retraces
# ---------------------------------------------------------------------------
def test_unified_builder_zero_extra_retraces(rng):
    a, rows, cols, vals = make_sparse(rng, 120, 100, 0.06, n_dense_rows=4)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    b = jnp.asarray(rng.randn(100, 24).astype(np.float32))
    spmm.execute(plan, b).block_until_ready()
    before = fused_trace_count()
    for _ in range(3):
        spmm.execute(plan, b).block_until_ready()
    # a re-prepared identical structure reuses the same compiled program
    plan2 = spmm.prepare(rows, cols, vals, a.shape, cfg)
    assert plan2.signature() == plan.signature()
    spmm.execute(plan2, b).block_until_ready()
    assert fused_trace_count() == before


def test_sharded_builder_zero_extra_retraces(rng):
    a, rows, cols, vals = make_sparse(rng, 96, 64, 0.07, n_dense_rows=3)
    cfg = spmm.SpmmConfig(impl="xla")
    mesh = make_spmm_mesh(1)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh, cfg,
                                 shard_axis="rows")
    b = jnp.asarray(rng.randn(64, 16).astype(np.float32))
    spmm.execute_sharded(splan, b).block_until_ready()
    before = sharded_trace_count()
    splan2 = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh, cfg,
                                  shard_axis="rows")
    spmm.execute_sharded(splan2, b).block_until_ready()
    assert sharded_trace_count() == before


def test_delta_capacity_bounds_retraces(rng):
    """Sidecar capacity growth (pow2) is the only retrace driver for a
    mutation stream through the unified builder."""
    a, rows, cols, vals = make_sparse(rng, 80, 64, 0.08, n_dense_rows=3)
    plan = spmm.prepare(rows, cols, vals, a.shape, spmm.SpmmConfig(impl="xla"))
    dp = DynamicPlan(plan, auto_compact=False)
    dense = _dense(rows, cols, vals, a.shape)
    zr, zc = np.nonzero(dense == 0)
    order = rng.permutation(zr.size)[:24]
    b = jnp.asarray(rng.randn(64, 8).astype(np.float32))
    dp.update(GraphDelta.inserts(zr[order[:1]], zc[order[:1]],
                                 np.ones(1)))
    dp.execute(b)
    before = fused_trace_count()
    caps = set()
    for j in order[1:]:
        dp.update(GraphDelta.inserts(zr[j:j + 1], zc[j:j + 1], np.ones(1)))
        dp.execute(b)
        caps.add(dp._materialize().capacity)
    assert fused_trace_count() - before <= len(caps)


# ---------------------------------------------------------------------------
# bounded cache
# ---------------------------------------------------------------------------
def test_executor_cache_is_bounded_and_evicts(rng):
    """The per-signature executor cache is one bounded LRU: capacity set
    through SpmmConfig caps it, and evicted structures retrace on return
    (bounded memory in long-lived services, correctness preserved)."""
    prev_capacity = EXECUTOR_CACHE.capacity
    try:
        plans = []
        for m in (64, 80, 96):  # three distinct structures
            a, rows, cols, vals = make_sparse(rng, m, 48, 0.08)
            cfg = spmm.SpmmConfig(impl="xla", executor_cache_capacity=2)
            plans.append(spmm.prepare(rows, cols, vals, a.shape, cfg))
        b = jnp.asarray(rng.randn(48, 8).astype(np.float32))
        dense_b = np.asarray(b, np.float64)
        for p in plans:
            spmm.execute(p, b)
        assert EXECUTOR_CACHE.capacity == 2
        assert len(EXECUTOR_CACHE) <= 2
        # plans[0] was evicted (LRU): executing it again retraces — and is
        # still correct
        before = fused_trace_count()
        out = spmm.execute(plans[0], b)
        assert fused_trace_count() == before + 1
        m0 = plans[0].shape[0]
        expect = _dense(*map(np.asarray, (
            plans[0].update_maps.rows, plans[0].update_maps.cols,
            plans[0].update_maps.vals)), (m0, 48)) @ dense_b
        _check(out, expect)
        # the still-cached newest structure does not retrace
        before = fused_trace_count()
        spmm.execute(plans[2], b)
        assert fused_trace_count() == before
    finally:
        EXECUTOR_CACHE.set_capacity(prev_capacity)


def test_build_executor_identity_per_flavor(rng):
    """One cache entry per (sig, batch, delta, mesh) tuple; no aliasing."""
    a, rows, cols, vals = make_sparse(rng, 64, 48, 0.08)
    plan = spmm.prepare(rows, cols, vals, a.shape, spmm.SpmmConfig(impl="xla"))
    sig = plan.signature()
    assert build_executor(sig) is build_executor(sig)
    assert build_executor(sig) is not build_executor(sig, batch=2)
    delta = build_delta_fringe(np.array([0]), np.array([0]), np.array([1.0]),
                               a.shape, plan.config)
    assert build_executor(sig, delta_sig=delta.sig) is not build_executor(sig)
    with pytest.raises(ValueError, match="need a mesh"):
        build_executor(sig, shard_axis="rows")
