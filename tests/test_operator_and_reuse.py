"""SpMMOperator differentiation + reuse-planner properties."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import reuse, spmm
from repro.core.spmm import SpMMOperator
from conftest import make_sparse


def test_spmm_operator_forward_and_grad(rng):
    a, rows, cols, vals = make_sparse(rng, 120, 100, 0.06, n_dense_rows=6)
    b = jnp.asarray(rng.randn(100, 64).astype(np.float32))
    op = SpMMOperator(rows, cols, vals, a.shape, spmm.SpmmConfig(impl="xla"))
    out = np.asarray(op(b))
    np.testing.assert_allclose(out, a @ np.asarray(b), rtol=1e-4, atol=1e-4)

    # dL/dB for L = sum(A @ B * W) is A^T @ W
    w = jnp.asarray(rng.randn(120, 64).astype(np.float32))
    grad = jax.grad(lambda bb: jnp.sum(op(bb) * w))(b)
    expect = a.T @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(grad), expect, rtol=1e-4, atol=1e-4)


def test_spmm_operator_inside_jit(rng):
    a, rows, cols, vals = make_sparse(rng, 64, 64, 0.1)
    op = SpMMOperator(rows, cols, vals, a.shape, spmm.SpmmConfig(impl="xla"))
    b = jnp.asarray(rng.randn(64, 32).astype(np.float32))
    f = jax.jit(lambda x: op(x * 2.0))
    np.testing.assert_allclose(np.asarray(f(b)), a @ (2 * np.asarray(b)),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 99), nw=st.integers(1, 40), nb=st.integers(1, 6),
       kblocks=st.integers(2, 12))
def test_reuse_plan_is_permutation_and_never_worse(seed, nw, nb, kblocks):
    r = np.random.RandomState(seed)
    nb = min(nb, kblocks)
    num_blocks = r.randint(1, nb + 1, nw)
    block_cols = np.zeros((nw, nb), np.int64)
    for w in range(nw):
        block_cols[w, : num_blocks[w]] = np.sort(
            r.choice(kblocks, num_blocks[w], replace=False))
    clusters = np.sort(r.randint(0, 4, nw))
    plan = reuse.plan_window_order(block_cols, num_blocks, clusters)
    # permutation of all windows
    assert sorted(plan.window_order.tolist()) == list(range(nw))
    # copy elision can only help
    assert plan.est_b_blocks_loaded <= plan.est_b_blocks_naive
    assert plan.reuse_factor >= 1.0


def test_reuse_plan_elides_shared_leading_blocks():
    # 4 windows in one cluster all leading with block 7 -> 3 elided loads
    block_cols = np.array([[7, 1], [7, 2], [7, 3], [7, 4]])
    num_blocks = np.array([2, 2, 2, 2])
    plan = reuse.plan_window_order(block_cols, num_blocks, np.zeros(4, np.int64))
    assert plan.est_b_blocks_naive == 8
    assert plan.est_b_blocks_loaded == 8 - 3


def test_capacity_bound_splits_clusters():
    # one cluster touching 10 distinct blocks with capacity 4 gets split
    block_cols = np.arange(10).reshape(10, 1)
    num_blocks = np.ones(10, np.int64)
    plan = reuse.plan_window_order(
        block_cols, num_blocks, np.zeros(10, np.int64),
        capacity_blocks=5, capacity_frac=0.8)
    assert plan.working_set_blocks <= 4
    assert sorted(plan.window_order.tolist()) == list(range(10))


def test_tile_shape_selector_respects_constraints():
    t = reuse.select_tile_shape(n_cols=256)
    assert t.bm % 128 == 0 and t.bn % 128 == 0 and t.bk % 8 == 0
    assert t.vmem_bytes() <= reuse.VMEM_BYTES // 2
    # the paper's asymmetry: N-heavy beats K-heavy at equal volume
    assert t.bn >= t.bk
