"""Degenerate-input hardening: one regression test per audited edge case.

The audit behind this file: ``prepare``/``execute`` over nnz=0, single
row/column matrices, M/K smaller than one window, zero-dim operands,
duplicate COO entries, non-f32 value dtypes, and all-fringe/all-core
splits — plus the input-validation errors that replaced silent
negative-index aliasing and cryptic out-of-range failures.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spmm


def _run_vs_dense(rows, cols, vals, shape, n=8, impl="xla", **cfg_kwargs):
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    cfg = spmm.SpmmConfig(impl=impl, **cfg_kwargs)
    plan = spmm.prepare(rows, cols, vals, shape, cfg)
    b = np.random.RandomState(0).randn(shape[1], n).astype(np.float32)
    out = np.asarray(spmm.execute(plan, jnp.asarray(b)))
    a = np.zeros(shape, np.float64)
    if rows.size:
        np.add.at(a, (rows, cols), vals.astype(np.float64))
    np.testing.assert_allclose(out, (a @ b).astype(np.float32),
                               rtol=1e-4, atol=1e-4)
    return plan


# --- empty / zero-dim shapes ------------------------------------------------
def test_nnz_zero():
    _run_vs_dense([], [], [], (4, 6))


def test_zero_rows_matrix():
    plan = _run_vs_dense([], [], [], (0, 5))
    assert np.asarray(
        spmm.execute(plan, jnp.ones((5, 3), jnp.float32))).shape == (0, 3)


def test_zero_cols_matrix():
    _run_vs_dense([], [], [], (5, 0), n=3)


def test_zero_width_rhs():
    plan = _run_vs_dense([0], [0], [1.0], (2, 2))
    out = spmm.execute(plan, jnp.zeros((2, 0), jnp.float32))
    assert out.shape == (2, 0)


# --- tiny shapes (below one window / one k-block) ---------------------------
def test_one_by_one():
    _run_vs_dense([0], [0], [2.0], (1, 1))


def test_single_row_matrix():
    _run_vs_dense([0, 0, 0], [0, 2, 4], [1.0, 2.0, 3.0], (1, 5))


def test_single_col_matrix():
    _run_vs_dense([0, 2, 4], [0, 0, 0], [1.0, 2.0, 3.0], (5, 1))


def test_m_and_k_below_one_window():
    # bm=128/bk=64 defaults: a 3x3 matrix fits in a fraction of one tile
    _run_vs_dense([0, 1, 2], [0, 1, 2], [1.0, 2.0, 3.0], (3, 3))


def test_single_column_rhs():
    _run_vs_dense([0, 1], [1, 0], [1.0, 2.0], (2, 2), n=1)


# --- forced split extremes --------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_all_fringe_tiny(impl):
    _run_vs_dense([0, 0, 1, 1], [0, 1, 0, 1], [1.0, 2.0, 3.0, 4.0], (2, 2),
                  impl=impl, alpha=1.0)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_all_core_tiny(impl):
    _run_vs_dense([0, 0, 1, 1], [0, 1, 0, 1], [1.0, 2.0, 3.0, 4.0], (2, 2),
                  impl=impl, alpha=1e-12, enable_col_stage=False)


# --- value handling ---------------------------------------------------------
def test_duplicate_coo_entries_accumulate():
    _run_vs_dense([0, 0, 1, 1, 1], [1, 1, 0, 0, 0],
                  [1.0, 2.0, 3.0, 4.0, 5.0], (64, 64))


@pytest.mark.parametrize("dtype", [np.float64, np.int32])
def test_value_dtypes_cast_to_f32(dtype):
    plan = _run_vs_dense([0, 1], [1, 0], np.array([1.5, 2.5]).astype(dtype),
                         (2, 2))
    # fringe values are cast once at prepare; kernels never see int/f64
    assert plan.fringe_vals.dtype == jnp.float32
    assert plan.fringe_kb_vals.dtype == jnp.float32


# --- input validation (silent-corruption regressions) -----------------------
def test_negative_row_index_rejected():
    # pre-fix: -1 wrapped python-style and aliased onto the last row
    with pytest.raises(ValueError, match="row indices out of range"):
        spmm.prepare(np.array([-1]), np.array([0]),
                     np.array([1.0], np.float32), (4, 4))


def test_out_of_range_col_rejected():
    with pytest.raises(ValueError, match="col indices out of range"):
        spmm.prepare(np.array([0]), np.array([9]),
                     np.array([1.0], np.float32), (4, 4))


def test_mismatched_triplet_lengths_rejected():
    with pytest.raises(ValueError, match="lengths disagree"):
        spmm.prepare(np.array([0, 1]), np.array([0]),
                     np.array([1.0], np.float32), (4, 4))


def test_non_integer_indices_rejected():
    with pytest.raises(ValueError, match="integer"):
        spmm.prepare(np.array([0.0]), np.array([0]),
                     np.array([1.0], np.float32), (4, 4))


def test_bad_rhs_rank_rejected():
    plan = spmm.prepare(np.array([0]), np.array([0]),
                        np.array([1.0], np.float32), (2, 2))
    # pre-fix: a rank-4 operand died as "too many values to unpack"
    with pytest.raises(ValueError, match="batch"):
        spmm.execute(plan, jnp.zeros((2, 2, 2, 2), jnp.float32))


def test_mismatched_rhs_k_rejected():
    # pre-fix: a short b zero-padded up to the plan's k_pad inside the
    # executor and nonzeros beyond b's K silently multiplied zero rows
    plan = spmm.prepare(np.array([0]), np.array([99]),
                        np.array([1.0], np.float32), (2, 100))
    with pytest.raises(ValueError, match="does not match the plan"):
        spmm.execute(plan, jnp.zeros((96, 4), jnp.float32))
    with pytest.raises(ValueError, match="does not match the plan"):
        spmm.execute(plan, jnp.zeros((3, 96, 4), jnp.float32))
