"""Training substrate: convergence, microbatch equivalence, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import pipeline
from repro.models.config import ModelConfig
from repro.train import compression, optimizer as opt_lib, train_loop

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  kv_chunk=16, compute_dtype=jnp.float32)
DCFG = pipeline.DataConfig(global_batch=4, seq_len=32, vocab_size=128)


def _batches(n):
    return [jax.tree.map(jnp.asarray, pipeline.make_batch(DCFG, s))
            for s in range(n)]


def test_loss_decreases():
    tcfg = train_loop.TrainConfig(
        optimizer=opt_lib.OptimizerConfig(lr=1e-3, warmup_steps=2,
                                          total_steps=50))
    params, opt = train_loop.init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    step = jax.jit(train_loop.make_train_step(CFG, tcfg))
    losses = []
    for b in _batches(15):
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_microbatch_equivalence():
    """scan-accumulated, unrolled, and single-shot grads must agree."""
    opt_cfg = opt_lib.OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    batch = _batches(1)[0]
    outs = {}
    for name, kw in [
        ("single", dict(num_microbatches=1)),
        ("scan", dict(num_microbatches=2)),
        ("unroll", dict(num_microbatches=2, unroll_microbatches=True)),
    ]:
        tcfg = train_loop.TrainConfig(optimizer=opt_cfg, **kw)
        params, opt = train_loop.init_train_state(
            jax.random.PRNGKey(0), CFG, tcfg)
        step = jax.jit(train_loop.make_train_step(CFG, tcfg))
        p2, _, m = step(params, opt, batch)
        outs[name] = (jax.tree.leaves(p2), float(m["loss"]))
    for a, b in [("scan", "unroll"), ("single", "scan")]:
        for x, y in zip(outs[a][0], outs[b][0]):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-3, atol=2e-4)
    assert outs["scan"][1] == pytest.approx(outs["unroll"][1], rel=1e-5)


def test_optimizer_schedule():
    cfg = opt_lib.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                  min_lr_frac=0.1)
    assert float(opt_lib.schedule(jnp.asarray(0), cfg)) == 0.0
    assert float(opt_lib.schedule(jnp.asarray(10), cfg)) == pytest.approx(1.0)
    assert float(opt_lib.schedule(jnp.asarray(100), cfg)) == pytest.approx(0.1)


def test_grad_clip():
    cfg = opt_lib.OptimizerConfig(clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = opt_lib.init_opt_state(params, cfg)
    _, _, m = opt_lib.apply_updates(params, grads, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_compression_error_feedback_telescopes():
    """Property: with error feedback, the cumulative applied update tracks
    the cumulative true gradient (bias telescopes away)."""
    rng = np.random.RandomState(0)
    g_true = [rng.randn(64).astype(np.float32) * 10 ** rng.randn()
              for _ in range(20)]
    err = {"g": jnp.zeros(64)}
    applied = np.zeros(64)
    for g in g_true:
        deq, err = compression.compress_grads_with_feedback(
            {"g": jnp.asarray(g)}, err)
        applied += np.asarray(deq["g"])
    total_true = np.sum(g_true, axis=0)
    # final residual bounds the divergence
    resid = np.abs(np.asarray(err["g"])).max()
    assert np.abs(applied - total_true).max() <= resid + 1e-4


def test_compression_quantization_error_bounded():
    g = {"w": jnp.asarray(np.random.RandomState(1).randn(1000) * 5)}
    err0 = compression.init_error_feedback(g)
    deq, err = compression.compress_grads_with_feedback(g, err0)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.abs(err["w"]).max()) <= scale * 0.5 + 1e-6
