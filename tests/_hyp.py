"""Graceful hypothesis import guard.

``hypothesis`` is an optional dev dependency (see requirements.txt).  When
it is installed, this module re-exports the real ``given``/``settings``/
``st``.  When it is missing, property-based tests are collected but skipped
(importorskip-style, at function granularity) so the rest of each module's
tests still run and the suite collects everywhere.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: any strategy call -> None."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg replacement: hypothesis-provided parameters must not
            # be mistaken for pytest fixtures during collection
            def skipper():
                pytest.skip("hypothesis not installed (property-based test)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
