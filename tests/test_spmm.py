"""End-to-end NeutronSparse SpMM vs dense matmul (paper Fig. 7 pipeline)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import spmm
from repro.data import graphs
from conftest import make_sparse


def _check(a, rows, cols, vals, b, cfg, tol=1e-4):
    out = np.asarray(spmm.neutron_spmm(rows, cols, vals, a.shape,
                                       jnp.asarray(b), cfg))
    expect = a.astype(np.float64) @ b.astype(np.float64)
    scale = np.abs(expect).max() + 1e-9
    assert np.abs(out - expect).max() / scale < tol


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_spmm_correct(rng, impl):
    a, rows, cols, vals = make_sparse(rng, 200, 160, 0.05, n_dense_rows=10)
    b = rng.randn(160, 256).astype(np.float32)
    _check(a, rows, cols, vals, b, spmm.SpmmConfig(impl=impl))


@pytest.mark.parametrize("kwargs", [
    dict(enable_global_reorder=False),
    dict(enable_local_reorder=False),
    dict(enable_col_stage=False),
    dict(enable_reuse_order=False),
    dict(reorder_cols=True),
    dict(alpha=0.5),
    dict(bm=64, bk=32, bn=128),
])
def test_spmm_flag_matrix(rng, kwargs):
    a, rows, cols, vals = make_sparse(rng, 150, 130, 0.08, n_dense_rows=6)
    b = rng.randn(130, 200).astype(np.float32)
    _check(a, rows, cols, vals, b, spmm.SpmmConfig(impl="xla", **kwargs))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 30), density=st.floats(0.01, 0.3),
       n=st.sampled_from([64, 100, 256]))
def test_spmm_property(seed, density, n):
    r = np.random.RandomState(seed)
    m, k = 90, 110
    a = ((r.rand(m, k) < density) * r.randn(m, k)).astype(np.float32)
    rows, cols = np.nonzero(a)
    if len(rows) == 0:
        return
    vals = a[rows, cols]
    b = r.randn(k, n).astype(np.float32)
    _check(a, rows, cols, vals, b, spmm.SpmmConfig(impl="xla"))


def test_paper_dataset_generators():
    for name in ("cora", "reddit", "F1"):
        spec = graphs.PAPER_DATASETS[name]
        spec = dataclasses.replace(spec, m=min(spec.m, 2048), k=min(spec.k, 2048))
        rows, cols, vals = graphs.generate(spec)
        stats = graphs.dataset_stats(rows, cols, (spec.m, spec.k))
        assert stats["nnz"] > 0
        assert 0 <= stats["skew_top10"] <= 1
        a = np.zeros((spec.m, spec.k), np.float32)
        a[rows, cols] = vals
        b = np.random.RandomState(0).randn(spec.k, 64).astype(np.float32)
        _check(a, rows, cols, vals, b, spmm.SpmmConfig(impl="xla"), tol=1e-3)


def test_epoch_loop_adapts(rng):
    a, rows, cols, vals = make_sparse(rng, 256, 128, 0.05, n_dense_rows=16)
    b = jnp.asarray(rng.randn(128, 128).astype(np.float32))
    op = spmm.NeutronSpMM(rows, cols, vals, a.shape,
                          spmm.SpmmConfig(impl="xla"))
    outs = [np.asarray(op.run_epoch(b)) for _ in range(4)]
    expect = a @ np.asarray(b)
    for o in outs:  # migration must never break correctness
        np.testing.assert_allclose(o, expect, rtol=1e-4, atol=1e-4)
    assert len(op.epoch_log) == 4


def test_stats_recorded(rng):
    a, rows, cols, vals = make_sparse(rng, 100, 100, 0.05, n_dense_rows=4)
    plan = spmm.prepare(rows, cols, vals, a.shape, spmm.SpmmConfig())
    sd = plan.stats_dict
    for key in ("alpha", "fringe_fraction", "tile_density", "reuse_factor",
                "t_partition_s", "t_reorder_s"):
        assert key in sd
    assert sd["nnz"] == len(rows)
