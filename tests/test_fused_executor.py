"""Fused single-dispatch executor: oracle equivalence, chunked fringe
kernel sweeps, and retrace-count guarantees."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spmm
from repro.data import graphs
from repro.kernels import ref
from repro.kernels.gather_spmm import gather_spmm
from conftest import make_sparse

PANEL = ["cora", "wiki-RfA", "ogbn-arxiv", "F1", "reddit"]


def _load(name, max_dim=512):
    spec = graphs.PAPER_DATASETS[name]
    spec = dataclasses.replace(spec, m=min(spec.m, max_dim),
                               k=min(spec.k, max_dim))
    rows, cols, vals = graphs.generate(spec)
    return rows, cols, vals, (spec.m, spec.k)


# ---------------------------------------------------------------------------
# fused execute == matrix path + vector path (dataset panel oracle)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", PANEL)
def test_fused_matches_two_path_sum_on_panel(name):
    rows, cols, vals, shape = _load(name)
    b = jnp.asarray(
        np.random.RandomState(0).randn(shape[1], 64).astype(np.float32))
    plan = spmm.prepare(rows, cols, vals, shape, spmm.SpmmConfig(impl="xla"))
    fused = np.asarray(spmm.execute(plan, b))
    two_path = np.asarray(
        spmm.execute_matrix_path(plan, b) + spmm.execute_vector_path(plan, b))
    np.testing.assert_allclose(fused, two_path, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("alpha", [None, 1.0, 1e-9])
def test_fused_matches_dense_reference(rng, alpha):
    """Including the all-fringe (alpha=1) and all-core (alpha~0) splits that
    exercise the empty-path short-circuits."""
    a, rows, cols, vals = make_sparse(rng, 150, 130, 0.08, n_dense_rows=6)
    b = rng.randn(130, 64).astype(np.float32)
    cfg = spmm.SpmmConfig(impl="xla", alpha=alpha,
                          enable_col_stage=alpha is None)
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    out = np.asarray(spmm.execute(plan, jnp.asarray(b)))
    expect = a.astype(np.float64) @ b.astype(np.float64)
    scale = np.abs(expect).max() + 1e-9
    assert np.abs(out - expect).max() / scale < 1e-4


def test_empty_path_short_circuits(rng):
    """Empty paths return exact zeros without dispatching dummy kernels."""
    a, rows, cols, vals = make_sparse(rng, 64, 64, 0.05)
    b = jnp.asarray(rng.randn(64, 32).astype(np.float32))
    all_fringe = spmm.prepare(rows, cols, vals, a.shape,
                              spmm.SpmmConfig(impl="xla", alpha=1.0))
    assert not all_fringe.has_core
    assert np.all(np.asarray(spmm.execute_matrix_path(all_fringe, b)) == 0.0)
    all_core = spmm.prepare(
        rows, cols, vals, a.shape,
        spmm.SpmmConfig(impl="xla", alpha=1e-12, enable_col_stage=False))
    assert not all_core.has_fringe
    assert np.all(np.asarray(spmm.execute_vector_path(all_core, b)) == 0.0)


def test_empty_matrix_executes_to_zeros():
    plan = spmm.prepare(np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, np.float32), (32, 48),
                        spmm.SpmmConfig(impl="xla"))
    b = jnp.ones((48, 16), jnp.float32)
    assert np.all(np.asarray(spmm.execute(plan, b)) == 0.0)


# ---------------------------------------------------------------------------
# chunked fringe kernel vs oracle
# ---------------------------------------------------------------------------
def _sorted_coo(rng, num_rows, kk, nnz):
    rows = np.sort(rng.randint(0, num_rows, nnz)).astype(np.int32)
    for r in range(num_rows):  # every packed row owns >= 1 nonzero
        if r not in rows:
            rows[rng.randint(nnz)] = r
    rows = np.sort(rows)
    cols = rng.randint(0, kk, nnz).astype(np.int32)
    vals = rng.randn(nnz).astype(np.float32)
    return rows, cols, vals


@pytest.mark.parametrize("chunk", [1, 2, 3, 8, 16])
@pytest.mark.parametrize("nnz", [5, 40, 64])
def test_chunked_gather_matches_ref(chunk, nnz):
    """Sweep chunk sizes incl. non-divisors of nnz (padded tail chunks)."""
    rng = np.random.RandomState(chunk * 100 + nnz)
    num_rows, kk = 7, 32
    rows, cols, vals = _sorted_coo(rng, num_rows, kk, nnz)
    b = jnp.asarray(rng.randn(kk, 128).astype(np.float32))
    out = gather_spmm(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                      b, num_rows=num_rows, bn=128, chunk=chunk,
                      interpret=True)
    expect = ref.ref_gather_spmm(jnp.asarray(rows), jnp.asarray(cols),
                                 jnp.asarray(vals), b, num_rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_chunked_gather_segment_boundaries():
    """Row runs crossing chunk edges must accumulate across grid steps."""
    # rows: run of 5 zeros then 3 ones -> with chunk=4 the row-0 run spans
    # two chunks and row 1 starts mid-chunk
    rows = jnp.asarray(np.array([0, 0, 0, 0, 0, 1, 1, 1], np.int32))
    cols = jnp.asarray(np.array([0, 1, 2, 0, 1, 2, 2, 3], np.int32))
    vals = jnp.asarray(np.arange(1.0, 9.0, dtype=np.float32))
    b = jnp.asarray(np.random.RandomState(3).randn(4, 128).astype(np.float32))
    for chunk in (1, 2, 4, 8):
        out = gather_spmm(rows, cols, vals, b, num_rows=2, bn=128,
                          chunk=chunk, interpret=True)
        expect = ref.ref_gather_spmm(rows, cols, vals, b, 2)
        # fp32 accumulation order differs between run-wise and segment sums
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("chunk", [3, 16, None])
def test_ref_gather_chunked_matches_oneshot(chunk):
    rng = np.random.RandomState(7)
    rows, cols, vals = _sorted_coo(rng, 9, 24, 50)
    b = jnp.asarray(rng.randn(24, 96).astype(np.float32))
    out = ref.ref_gather_spmm(jnp.asarray(rows), jnp.asarray(cols),
                              jnp.asarray(vals), b, 9, chunk=chunk)
    expect = ref.ref_gather_spmm(jnp.asarray(rows), jnp.asarray(cols),
                                 jnp.asarray(vals), b, 9)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# VMEM dispatch tiers: resident / K-sharded / XLA fallback
# ---------------------------------------------------------------------------
def _fringe_problem(rng, m=60, k=96, nnz=400, n=128):
    rows = rng.randint(0, m, nnz)
    cols = rng.randint(0, k, nnz)
    vals = rng.randn(nnz).astype(np.float32)
    a = np.zeros((m, k), np.float32)
    np.add.at(a, (rows, cols), vals)
    b = rng.randn(k, n).astype(np.float32)
    return rows, cols, vals, a, b


# budgets sized for k=96, ~60 packed rows, bn=128: huge -> resident;
# 60 kB fits only a k-slice -> ksharded; 4 kB fits nothing -> xla
@pytest.mark.parametrize("budget,tier", [
    (None, "resident"), (60_000, "ksharded"), (4_096, "xla"),
])
def test_dispatch_tier_forced_by_budget(rng, budget, tier):
    """Each tier, forced via a synthetic VMEM budget, matches the dense
    reference under the pallas (interpret) impl."""
    rows, cols, vals, a, b = _fringe_problem(rng)
    cfg = spmm.SpmmConfig(impl="pallas_interpret", bn=128, alpha=1.0,
                          fringe_vmem_budget=budget)
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    assert plan.fringe_tier == tier
    assert (plan.fringe_bk > 0) == (tier == "ksharded")
    out = np.asarray(spmm.execute(plan, jnp.asarray(b)))
    expect = a.astype(np.float64) @ b.astype(np.float64)
    scale = np.abs(expect).max() + 1e-9
    assert np.abs(out - expect).max() / scale < 1e-4


def test_over_budget_fringe_runs_ksharded(rng):
    """(K + packed_rows) * bn * 4 > 12 MB — the shape that used to raise the
    hard VMEM ValueError — now executes via the K-sharded tier under
    impl='pallas*' and matches the XLA reference."""
    m, k, nnz = 160, 12800, 1200
    rows = rng.randint(0, m, nnz)
    cols = rng.randint(0, k, nnz)
    vals = rng.randn(nnz).astype(np.float32)
    from repro.core.cost_model import FRINGE_VMEM_BUDGET, fringe_resident_bytes
    assert fringe_resident_bytes(k, m, 256) > FRINGE_VMEM_BUDGET
    cfg = spmm.SpmmConfig(impl="pallas_interpret", alpha=1.0)
    plan = spmm.prepare(rows, cols, vals, (m, k), cfg)
    assert plan.fringe_tier == "ksharded" and plan.fringe_bk % 8 == 0
    b = jnp.asarray(rng.randn(k, 256).astype(np.float32))
    out = np.asarray(spmm.execute(plan, b))
    xla_plan = spmm.prepare(rows, cols, vals, (m, k),
                            spmm.SpmmConfig(impl="xla", alpha=1.0))
    expect = np.asarray(spmm.execute(xla_plan, b))
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_kb_stream_matches_unbucketed_oracle(rng):
    """The plan-built k-bucketed stream is a pure relayout: the k-blocked
    oracle over it equals the plain gather oracle over the fringe COO."""
    rows, cols, vals, a, b = _fringe_problem(rng)
    cfg = spmm.SpmmConfig(impl="pallas_interpret", bn=128, alpha=1.0,
                          fringe_vmem_budget=60_000)
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    assert plan.fringe_tier == "ksharded"
    nr = int(plan.fringe_row_ids.shape[0])
    got = ref.ref_gather_spmm_kblocked(
        plan.fringe_kb_chunk, plan.fringe_kb_rows, plan.fringe_kb_cols,
        plan.fringe_kb_vals, jnp.asarray(b), nr, plan.fringe_bk)
    expect = ref.ref_gather_spmm(plan.fringe_rows, plan.fringe_cols,
                                 plan.fringe_vals, jnp.asarray(b), nr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_signature_distinguishes_tiers(rng):
    """Two plans differing only in dispatch tier must not alias one cached
    executor (same structure, different kernels)."""
    rows, cols, vals, a, b = _fringe_problem(rng)
    mk = lambda budget: spmm.prepare(
        rows, cols, vals, a.shape,
        spmm.SpmmConfig(impl="pallas_interpret", bn=128, alpha=1.0,
                        fringe_vmem_budget=budget))
    resident, ksharded, xla = mk(None), mk(60_000), mk(4_096)
    sigs = {p.signature() for p in (resident, ksharded, xla)}
    assert len(sigs) == 3
    b = jnp.asarray(b)
    outs = [np.asarray(spmm.execute(p, b)) for p in (resident, ksharded, xla)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# retrace behavior of the cached executor
# ---------------------------------------------------------------------------
def test_fused_executor_traces_once_across_epochs(rng):
    a, rows, cols, vals = make_sparse(rng, 120, 100, 0.06, n_dense_rows=4)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    b = jnp.asarray(rng.randn(100, 48).astype(np.float32))
    spmm.execute(plan, b).block_until_ready()
    before = spmm.fused_trace_count()
    for _ in range(5):  # same plan, repeated epochs
        spmm.execute(plan, b).block_until_ready()
    # re-prepared plan with identical structure (same signature) must reuse
    # the cached executor without tracing again
    plan2 = spmm.prepare(rows, cols, vals, a.shape, cfg)
    assert plan2.signature() == plan.signature()
    spmm.execute(plan2, b).block_until_ready()
    assert spmm.fused_trace_count() == before

    # a different operand width is a legitimate retrace (new jit shape)
    b2 = jnp.asarray(rng.randn(100, 32).astype(np.float32))
    spmm.execute(plan, b2).block_until_ready()
    assert spmm.fused_trace_count() == before + 1
