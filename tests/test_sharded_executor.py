"""Sharded executor: simulated-mesh parity, shard balancing, assembly maps,
shard-axis selection, and cache/signature behavior on 1-device meshes.

Multi-device coverage comes from two directions: the in-process tests below
marked with the device-count skip run directly when the suite is launched
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI mesh
leg), and ``test_forced_mesh_parity_subprocess`` always exercises the full
1/2/4/8-way panel by spawning a fresh process with the forced flag — so
single-device local runs still verify multi-device parity.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spmm
from repro.core.cost_model import default_cost_model, select_shard_axis
from repro.core.coordinator import window_costs_from_coo
from repro.launch.mesh import make_spmm_mesh
from conftest import make_sparse

N_DEVICES = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEVICES < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(covered by the subprocess parity test on 1-device hosts)",
)


def _problem(rng, m=300, k=120, density=0.08, dense_rows=6):
    a, rows, cols, vals = make_sparse(rng, m, k, density,
                                      n_dense_rows=dense_rows)
    return a, rows, cols, vals


# ---------------------------------------------------------------------------
# 1-device mesh: full machinery without forced devices
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shard_axis", ["rows", "rhs", "auto"])
def test_one_device_mesh_matches_execute(rng, shard_axis):
    a, rows, cols, vals = _problem(rng)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    b = jnp.asarray(rng.randn(a.shape[1], 32).astype(np.float32))
    ref = np.asarray(spmm.execute(plan, b))
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(1),
                                 cfg, shard_axis=shard_axis)
    out = np.asarray(spmm.execute_sharded(splan, b))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_one_device_mesh_batched(rng):
    a, rows, cols, vals = _problem(rng)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    b3 = jnp.asarray(rng.randn(4, a.shape[1], 16).astype(np.float32))
    ref = np.asarray(spmm.execute(plan, b3))
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(1),
                                 cfg, shard_axis="rows")
    out = np.asarray(spmm.execute_sharded(splan, b3))
    assert out.shape == (4, a.shape[0], 16)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_sharded_empty_matrix(rng):
    splan = spmm.prepare_sharded(
        np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(0, np.float32),
        (40, 24), make_spmm_mesh(1), spmm.SpmmConfig(impl="xla"))
    b = jnp.ones((24, 8), jnp.float32)
    assert np.all(np.asarray(spmm.execute_sharded(splan, b)) == 0.0)


def test_sharded_rejects_mismatched_rhs_k(rng):
    a, rows, cols, vals = _problem(rng)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(1),
                                 spmm.SpmmConfig(impl="xla"),
                                 shard_axis="rows")
    with pytest.raises(ValueError, match="does not match the plan"):
        spmm.execute_sharded(splan, jnp.zeros((a.shape[1] - 8, 4),
                                              jnp.float32))


def test_sharded_rejects_reorder_cols(rng):
    a, rows, cols, vals = _problem(rng)
    with pytest.raises(ValueError, match="reorder_cols"):
        spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(1),
                             spmm.SpmmConfig(impl="xla", reorder_cols=True))


def test_rhs_axis_one_shard_accepts_any_n(rng):
    a, rows, cols, vals = _problem(rng)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(1),
                                 spmm.SpmmConfig(impl="xla"),
                                 shard_axis="rhs")
    b = jnp.ones((a.shape[1], 7), jnp.float32)
    assert spmm.execute_sharded(splan, b).shape == (a.shape[0], 7)


def test_sharded_stats_record_balance(rng):
    a, rows, cols, vals = _problem(rng)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(1),
                                 spmm.SpmmConfig(impl="xla"),
                                 shard_axis="rows")
    sd = splan.stats_dict
    assert sd["n_shards"] == 1
    assert sd["rows_imbalance"] == pytest.approx(1.0)
    assert sum(sd["shard_nnz"]) == rows.shape[0]
    assert sum(sd["shard_rows"]) == a.shape[0]


def test_empty_windows_spread_across_shards(rng):
    """Zero-cost windows must not pile onto one shard via the LPT +0
    tie-break — that inflates m_loc_max (every shard's padded problem size
    and the all-gather volume).  8 costed + 8 empty windows over a mesh of
    1 still exposes the bookkeeping; the load balance assertion uses the
    recorded per-shard rows on a synthetic 2-shard assignment computed
    through prepare_sharded's own path on a 1-device mesh."""
    # alternate nonempty/empty windows: rows only in even windows
    bm = 128
    rows_list = []
    for w in range(0, 16, 2):
        rows_list.append(np.full(40, w * bm + 3, np.int64))
    rows = np.concatenate(rows_list)
    cols = np.tile(np.arange(40, dtype=np.int64), 8)
    vals = np.ones(rows.size, np.float32)
    splan = spmm.prepare_sharded(rows, cols, vals, (16 * bm, 64),
                                 make_spmm_mesh(1),
                                 spmm.SpmmConfig(impl="xla"),
                                 shard_axis="rows")
    # all 16 windows land somewhere, and the padded per-shard row count
    # covers exactly the whole matrix (no duplication, no loss)
    assert sum(splan.stats_dict["shard_rows"]) == 16 * bm
    b = jnp.asarray(np.random.RandomState(0).randn(64, 8).astype(np.float32))
    plan = spmm.prepare(rows, cols, vals, (16 * bm, 64),
                        spmm.SpmmConfig(impl="xla"))
    np.testing.assert_allclose(np.asarray(spmm.execute_sharded(splan, b)),
                               np.asarray(spmm.execute(plan, b)),
                               rtol=1e-5, atol=1e-5)


@needs8
def test_empty_windows_balance_padded_rows_in_process(rng):
    """On a real 8-way mesh: 8 costed + 8 empty windows -> every shard gets
    one of each (256 padded rows), not one shard with 9 windows."""
    bm = 128
    rows = np.concatenate(
        [np.full(40, w * bm + 3, np.int64) for w in range(0, 16, 2)])
    cols = np.tile(np.arange(40, dtype=np.int64), 8)
    vals = np.ones(rows.size, np.float32)
    splan = spmm.prepare_sharded(rows, cols, vals, (16 * bm, 64),
                                 make_spmm_mesh(8),
                                 spmm.SpmmConfig(impl="xla"),
                                 shard_axis="rows")
    assert splan.stats_dict["rows_per_shard_padded"] == 2 * bm
    assert all(r == 2 * bm for r in splan.stats_dict["shard_rows"])


# ---------------------------------------------------------------------------
# shard-axis estimator
# ---------------------------------------------------------------------------
def test_window_costs_respect_alpha_override():
    """A forced split (SpmmConfig.alpha analogue) re-prices windows by the
    engine that will actually run them."""
    cm = default_cost_model()
    rows = np.arange(128, dtype=np.int64).repeat(64)  # one dense-ish window
    wc_default = window_costs_from_coo(rows, 128, 128, 64, cm)
    wc_forced = window_costs_from_coo(rows, 128, 128, 64, cm, alpha=1.0)
    assert wc_default[0] == pytest.approx(cm.cost_matrix(128.0, 64))
    assert wc_forced[0] == pytest.approx(cm.cost_vector(128.0 * 64))



def test_window_costs_route_by_alpha_boundary():
    cm = default_cost_model()
    # window 0: one nonzero in 128 rows (far below alpha) -> vector cost;
    # window 1: fully dense -> matrix cost
    rows = np.concatenate([
        np.zeros(1, np.int64), 128 + np.arange(128).repeat(256) % 128])
    wc = window_costs_from_coo(rows, 256, 128, 256, cm)
    assert wc.shape == (2,)
    assert wc[0] == pytest.approx(cm.cost_vector(1.0))
    assert wc[1] == pytest.approx(cm.cost_matrix(128.0, 256))


def test_select_shard_axis_prefers_rows_when_balanced():
    d = select_shard_axis(np.ones(64), 8)
    assert d.shard_axis == "rows"
    assert d.rows_imbalance == pytest.approx(1.0)


def test_select_shard_axis_falls_to_rhs_on_skew():
    # one window dominates: LPT cannot balance 8 shards
    wc = np.ones(8)
    wc[0] = 100.0
    d = select_shard_axis(wc, 8)
    assert d.shard_axis == "rhs"
    assert d.rows_imbalance > 1.25


def test_select_shard_axis_falls_to_rhs_when_too_few_windows():
    d = select_shard_axis(np.ones(3), 8)
    assert d.shard_axis == "rhs"


def test_select_shard_axis_single_shard_and_empty():
    assert select_shard_axis(np.ones(4), 1).shard_axis == "rows"
    assert select_shard_axis(np.zeros(4), 8).shard_axis == "rows"


# ---------------------------------------------------------------------------
# signature / cache identity
# ---------------------------------------------------------------------------
def test_sharded_signature_never_aliases_plan_signature(rng):
    a, rows, cols, vals = _problem(rng)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    mesh = make_spmm_mesh(1)
    srows = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh, cfg,
                                 shard_axis="rows")
    srhs = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh, cfg,
                                shard_axis="rhs")
    sigs = {plan.signature(), srows.signature(), srhs.signature()}
    assert len(sigs) == 3


def test_sharded_executor_traces_once_per_structure(rng):
    a, rows, cols, vals = _problem(rng)
    cfg = spmm.SpmmConfig(impl="xla")
    mesh = make_spmm_mesh(1)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh, cfg,
                                 shard_axis="rows")
    b = jnp.asarray(rng.randn(a.shape[1], 24).astype(np.float32))
    spmm.execute_sharded(splan, b).block_until_ready()
    before = spmm.sharded_trace_count()
    spmm.execute_sharded(splan, b).block_until_ready()
    # re-prepared identical structure reuses the compiled executor
    splan2 = spmm.prepare_sharded(rows, cols, vals, a.shape, mesh, cfg,
                                  shard_axis="rows")
    assert splan2.sig == splan.sig
    spmm.execute_sharded(splan2, b).block_until_ready()
    assert spmm.sharded_trace_count() == before


# ---------------------------------------------------------------------------
# multi-device in-process (CI mesh leg) + subprocess parity (everywhere)
# ---------------------------------------------------------------------------
@needs8
@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_multi_device_parity_in_process(rng, n_shards):
    a, rows, cols, vals = _problem(rng, m=1000, k=200, dense_rows=8)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    b = jnp.asarray(rng.randn(a.shape[1], 32).astype(np.float32))
    ref = np.asarray(spmm.execute(plan, b))
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape,
                                 make_spmm_mesh(n_shards), cfg,
                                 shard_axis="rows")
    out = np.asarray(spmm.execute_sharded(splan, b))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@needs8
def test_multi_device_empty_shard_in_process(rng):
    # one 100-row window spread over 2 shards: the second is empty
    a, rows, cols, vals = _problem(rng, m=100, k=64, dense_rows=2)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    b = jnp.asarray(rng.randn(64, 16).astype(np.float32))
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(2),
                                 cfg, shard_axis="rows")
    assert 0 in splan.stats_dict["shard_rows"]
    np.testing.assert_allclose(
        np.asarray(spmm.execute_sharded(splan, b)),
        np.asarray(spmm.execute(plan, b)), rtol=1e-5, atol=1e-5)


@needs8
def test_rhs_axis_rejects_indivisible_n_in_process(rng):
    a, rows, cols, vals = _problem(rng)
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(4),
                                 spmm.SpmmConfig(impl="xla"),
                                 shard_axis="rhs")
    with pytest.raises(ValueError, match="divisible"):
        spmm.execute_sharded(splan, jnp.ones((a.shape[1], 30), jnp.float32))


def test_forced_mesh_parity_subprocess(forced_mesh_run):
    """Full 1/2/4/8-way parity panel in a forced-8-device subprocess (the
    acceptance-criterion check; runs on single-device hosts too)."""
    worker = os.path.join(os.path.dirname(__file__),
                          "_sharded_parity_worker.py")
    out = forced_mesh_run(worker, n_devices=8)
    assert "PARITY OK" in out.stdout
