"""Dynamic-update parity worker on a simulated multi-device mesh.

Asserts, on 2- and 4-way meshes with forced host devices:
- ``update_values`` over a rows-sharded plan produces leaves bit-identical
  to re-running ``prepare_sharded`` with the updated values, and executes
  identically;
- structural inserts/deletes through ``DynamicPlan`` match the fp64 dense
  oracle before and after a forced compaction (which re-shards);
- sharded + delta executes as ONE dispatch (the routed sidecar merges
  inside the shard_map program; ``exec.dispatch_count`` rises by exactly 1)
  and is bit-identical to the legacy two-dispatch formulation
  (``execute_sharded`` + ``execute_delta_contribution`` post-pass).

Launched by tests/test_dynamic.py through the ``forced_mesh_run`` conftest
fixture, and runnable standalone:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 JAX_PLATFORMS=cpu \
        PYTHONPATH=src python tests/_dynamic_sharded_worker.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.hostdevices import force_host_device_count  # noqa: E402

force_host_device_count(os.environ, 4)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import plan_ir, spmm  # noqa: E402
from repro.dynamic import (  # noqa: E402
    DynamicPlan, GraphDelta, build_delta_fringe, update_values,
)
from repro.exec import dispatch_count  # noqa: E402
from repro.launch.mesh import make_spmm_mesh  # noqa: E402


def _coo(seed, m, k, density):
    rng = np.random.RandomState(seed)
    mask = rng.rand(m, k) < density
    rows, cols = np.nonzero(mask)
    return rows.astype(np.int64), cols.astype(np.int64), rng.randn(rows.size)


def _dense(rows, cols, vals, shape):
    a = np.zeros(shape, np.float64)
    np.add.at(a, (rows, cols), vals)
    return a


def check(n_shards):
    rng = np.random.RandomState(n_shards)
    m, k = 96 * n_shards // 2, 64
    rows, cols, vals = _coo(n_shards, m, k, 0.08)
    mesh = make_spmm_mesh(n_shards)
    cfg = spmm.SpmmConfig(impl="xla")
    b = jnp.asarray(rng.randn(k, 16).astype(np.float32))

    # value-only parity, bit for bit
    splan = spmm.prepare_sharded(rows, cols, vals, (m, k), mesh, cfg,
                                 shard_axis="rows")
    idx = rng.choice(rows.size, 25, replace=False)
    nv = rng.randn(25)
    updated = update_values(splan, idx, nv)
    vals2 = vals.copy()
    vals2[idx] = nv
    ref = spmm.prepare_sharded(rows, cols, vals2, (m, k), mesh, cfg,
                               shard_axis="rows")
    for i, (got, want) in enumerate(zip(updated.leaves, ref.leaves)):
        assert np.array_equal(np.asarray(got), np.asarray(want)), (
            n_shards, "leaf", i)
    assert np.array_equal(
        np.asarray(spmm.execute_sharded(updated, b)),
        np.asarray(spmm.execute_sharded(ref, b)),
    ), (n_shards, "value exec")

    # structural oracle across shards, then a forced compaction (re-shard)
    dp = DynamicPlan(updated, auto_compact=False)
    dense = _dense(rows, cols, vals2, (m, k))
    zr, zc = np.nonzero(dense == 0)
    pick = rng.choice(zr.size, 18, replace=False)
    iv = rng.randn(18)
    dp.update(GraphDelta.inserts(zr[pick], zc[pick], iv))
    dense[zr[pick], zc[pick]] += iv
    dpick = rng.choice(rows.size, 9, replace=False)
    dp.update(GraphDelta.deletes(rows[dpick], cols[dpick]))
    dense[rows[dpick], cols[dpick]] = 0

    def assert_close():
        out = np.asarray(dp.execute(b))
        expect = dense @ np.asarray(b, np.float64)
        scale = np.abs(expect).max() + 1e-9
        assert np.abs(out - expect).max() / scale < 1e-4, (
            n_shards, "structural")

    assert_close()

    # --- single dispatch + bit-parity with the legacy two-dispatch form ---
    delta = dp._materialize()
    assert isinstance(delta, plan_ir.ShardedDeltaFringe), type(delta)
    before = dispatch_count()
    fused = np.asarray(dp.execute(b))
    assert dispatch_count() - before == 1, (
        n_shards, "sharded+delta must be ONE executor dispatch",
        dispatch_count() - before)
    # legacy formulation: base shard_map dispatch + a standalone (global-
    # coordinate) delta contribution added as a post-pass
    keys = np.fromiter(dp._overlay, np.int64, count=len(dp._overlay))
    targets = [dp._overlay[int(key)] for key in keys]
    base_sums = dp._base_key_sums(keys)
    in_base = dp.maps.lookup(keys // k, keys % k) >= 0
    dvals = np.array([
        (-base_sums[i] if t is None
         else (t - base_sums[i] if in_base[i] else t))
        for i, t in enumerate(targets)
    ], np.float64)
    plain = build_delta_fringe(keys // k, keys % k, dvals, (m, k), cfg)
    legacy = np.asarray(spmm.execute_sharded(dp.plan, b)) + np.asarray(
        spmm.execute_delta_contribution((m, k), cfg, plain, b)
    )
    assert np.array_equal(fused, legacy), (
        n_shards, "one-dispatch result must be bit-identical to the "
        "two-dispatch post-pass", float(np.abs(fused - legacy).max()))

    dp.compact()
    assert isinstance(dp.plan, spmm.ShardedPlan)
    assert dp.plan.n_shards == n_shards
    assert dp.delta_nnz == 0
    assert_close()
    print(f"{n_shards}-way dynamic parity ok")


if __name__ == "__main__":
    for n in (2, 4):
        check(n)
    print("DYNAMIC PARITY OK")
