"""Persistent plan registry: warm-start, delta state, corruption handling.

The robustness contract: a damaged or stale registry entry may cost a
re-``prepare()`` (``load_or_prepare`` falls back), but it must never be
silently served — truncated shards, mangled manifests, and format-version
drift all raise a clean :class:`RegistryError` first.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spmm
from repro.dynamic import (
    DynamicPlan, GraphDelta, PlanRegistry, RegistryError,
)
from repro.serve import SpmmService
from conftest import make_sparse

CFG = spmm.SpmmConfig(impl="xla")


def _graph(rng, m=80, k=64):
    a, rows, cols, vals = make_sparse(rng, m, k, 0.08, n_dense_rows=3)
    return a, rows, cols, vals


def _entry_dir(root, name):
    d = os.path.join(root, name)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    return os.path.join(d, steps[-1])


def test_registry_round_trip_without_prepare(rng, tmp_path):
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    dp = DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG))
    b = jnp.asarray(rng.randn(64, 12).astype(np.float32))
    want = np.asarray(dp.execute(b))
    reg.save("g", dp)

    before = spmm.prepare_call_count()
    restored = reg.load("g")
    assert spmm.prepare_call_count() == before  # no prepare() on restore
    assert np.array_equal(np.asarray(restored.execute(b)), want)
    # restored plans stay updatable (maps round-tripped)
    idx = rng.choice(rows.size, 5, replace=False)
    nv = rng.randn(5)
    restored.update(GraphDelta.updates(rows[idx], cols[idx], nv))
    vals2 = vals.copy().astype(np.float64)
    vals2[idx] = nv
    ref = spmm.prepare(rows, cols, vals2, a.shape, CFG)
    assert np.array_equal(np.asarray(restored.plan.fringe_vals),
                          np.asarray(ref.fringe_vals))


def test_registry_round_trips_delta_state(rng, tmp_path):
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    dp = DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG),
                     auto_compact=False)
    dense = np.zeros(a.shape, np.float64)
    np.add.at(dense, (rows, cols), vals)
    zr, zc = np.nonzero(dense == 0)
    pick = rng.choice(zr.size, 7, replace=False)
    iv = rng.randn(7)
    dp.update(GraphDelta.inserts(zr[pick], zc[pick], iv))
    dense[zr[pick], zc[pick]] += iv
    dp.update(GraphDelta.deletes(rows[:3], cols[:3]))
    dense[rows[:3], cols[:3]] = 0
    reg.save("g", dp)

    restored = reg.load("g")
    assert restored.delta_nnz == dp.delta_nnz
    b = jnp.asarray(rng.randn(64, 8).astype(np.float32))
    out = np.asarray(restored.execute(b))
    expect = dense @ np.asarray(b, np.float64)
    scale = np.abs(expect).max() + 1e-9
    assert np.abs(out - expect).max() / scale < 1e-4


def test_load_or_prepare_warm_and_cold(rng, tmp_path):
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    dp = reg.load_or_prepare("g", rows, cols, vals, a.shape, CFG)
    assert reg.has("g")
    before = spmm.prepare_call_count()
    warm = reg.load_or_prepare("g", rows, cols, vals, a.shape, CFG)
    assert spmm.prepare_call_count() == before  # warm: no prepare
    b = jnp.asarray(rng.randn(64, 8).astype(np.float32))
    assert np.array_equal(np.asarray(warm.execute(b)),
                          np.asarray(dp.execute(b)))
    # a different matrix under the same name must NOT reuse the entry
    vals2 = vals.copy()
    vals2[0] += 1.0
    cold = reg.load_or_prepare("g", rows, cols, vals2, a.shape, CFG)
    assert spmm.prepare_call_count() > before
    a2 = a.astype(np.float64).copy()
    a2[rows[0], cols[0]] += 1.0
    out = np.asarray(cold.execute(b))
    expect = a2 @ np.asarray(b, np.float64)
    assert np.abs(out - expect).max() / (np.abs(expect).max() + 1e-9) < 1e-4


def test_truncated_shard_raises_then_falls_back(rng, tmp_path):
    """A truncated shard file is a clean RegistryError, and load_or_prepare
    answers it with a fresh prepare — never a wrong answer."""
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    reg.save("g", DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG)))
    entry = _entry_dir(str(tmp_path), "g")
    victim = os.path.join(entry, "leaf_flat_values.s0.npy")
    size = os.path.getsize(victim)
    with open(victim, "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(RegistryError, match="corrupt|truncated"):
        reg.load("g")
    before = spmm.prepare_call_count()
    dp = reg.load_or_prepare("g", rows, cols, vals, a.shape, CFG)
    assert spmm.prepare_call_count() > before  # fell back to prepare
    b = jnp.asarray(rng.randn(64, 8).astype(np.float32))
    out = np.asarray(dp.execute(b))
    expect = a.astype(np.float64) @ np.asarray(b, np.float64)
    assert np.abs(out - expect).max() / (np.abs(expect).max() + 1e-9) < 1e-4


def test_shape_mismatched_shard_is_rejected(rng, tmp_path):
    """A shard that np.load accepts but that disagrees with its manifest
    (e.g. a partial write of a valid smaller array) is still rejected."""
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    reg.save("g", DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG)))
    entry = _entry_dir(str(tmp_path), "g")
    np.save(os.path.join(entry, "maps_vals.s0.npy"),
            np.zeros(3, np.float32))
    with pytest.raises(RegistryError, match="does not match its manifest"):
        reg.load("g")


def test_corrupt_manifest_raises(rng, tmp_path):
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    reg.save("g", DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG)))
    entry = _entry_dir(str(tmp_path), "g")
    with open(os.path.join(entry, "manifest.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(RegistryError, match="manifest"):
        reg.load("g")


def test_format_version_mismatch_raises(rng, tmp_path):
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    reg.save("g", DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG)))
    entry = _entry_dir(str(tmp_path), "g")
    mpath = os.path.join(entry, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["meta"]["plan_format_version"] = -1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(RegistryError, match="plan format"):
        reg.load("g")


def test_missing_entry_and_bad_names(tmp_path):
    reg = PlanRegistry(str(tmp_path))
    with pytest.raises(RegistryError, match="no registry entry"):
        reg.load("nope")
    with pytest.raises(RegistryError, match="filesystem-safe"):
        reg.save("../evil", None)


def _sharded_dplan(rng, rows, cols, vals, shape, shard_axis="rows"):
    from repro.launch.mesh import make_spmm_mesh

    splan = spmm.prepare_sharded(rows, cols, vals, shape, make_spmm_mesh(1),
                                 CFG, shard_axis=shard_axis)
    return DynamicPlan(splan, auto_compact=False)


def test_sharded_plan_round_trips_by_resharding(rng, tmp_path):
    """A sharded entry stores COO + config + shard axis and load() rebuilds
    the plan by re-sharding — mutations (value fast path + structural
    overlay) survive the round trip (closes the ROADMAP refusal)."""
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    dp = _sharded_dplan(rng, rows, cols, vals, a.shape)
    # mutate both layers before persisting
    dense = a.astype(np.float64).copy()
    dp.update(GraphDelta.updates(rows[:3], cols[:3], [5.0, -1.5, 2.25]))
    dense[rows[:3], cols[:3]] = [5.0, -1.5, 2.25]
    zr, zc = np.nonzero(dense == 0)
    dp.update(GraphDelta.inserts(zr[:4], zc[:4], [1.0, 2.0, 3.0, 4.0]))
    dense[zr[:4], zc[:4]] += [1.0, 2.0, 3.0, 4.0]
    reg.save("g", dp)

    restored = reg.load("g")  # mesh=None: rebuilt from the stored n_shards
    assert restored.is_sharded
    assert restored.plan.n_shards == 1
    assert restored.delta_nnz == dp.delta_nnz
    b = jnp.asarray(rng.randn(a.shape[1], 8).astype(np.float32))
    out = np.asarray(restored.execute(b))
    expect = dense @ np.asarray(b, np.float64)
    assert np.abs(out - expect).max() / (np.abs(expect).max() + 1e-9) < 1e-4


def test_sharded_truncated_shard_raises_then_falls_back(rng, tmp_path):
    """Corruption handling mirrors the single-device entries: truncated
    data raises a clean RegistryError and load_or_prepare_sharded answers
    with a fresh prepare_sharded."""
    from repro.launch.mesh import make_spmm_mesh

    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    reg.save("g", _sharded_dplan(rng, rows, cols, vals, a.shape))
    entry = _entry_dir(str(tmp_path), "g")
    victim = os.path.join(entry, "coo_vals.s0.npy")
    with open(victim, "r+b") as f:
        f.truncate(os.path.getsize(victim) // 2)
    with pytest.raises(RegistryError, match="corrupt|truncated"):
        reg.load("g")
    dp = reg.load_or_prepare_sharded(
        "g", rows, cols, vals, a.shape, make_spmm_mesh(1),
        CFG, shard_axis="rows",
    )
    b = jnp.asarray(rng.randn(a.shape[1], 8).astype(np.float32))
    out = np.asarray(dp.execute(b))
    expect = a.astype(np.float64) @ np.asarray(b, np.float64)
    assert np.abs(out - expect).max() / (np.abs(expect).max() + 1e-9) < 1e-4


def test_sharded_manifest_and_version_corruption(rng, tmp_path):
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    reg.save("g", _sharded_dplan(rng, rows, cols, vals, a.shape))
    entry = _entry_dir(str(tmp_path), "g")
    mpath = os.path.join(entry, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    # version drift is rejected before any array is touched
    manifest["meta"]["plan_format_version"] = -1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(RegistryError, match="plan format"):
        reg.load("g")
    # a mangled manifest is rejected too
    with open(mpath, "w") as f:
        f.write("{not json")
    with pytest.raises(RegistryError, match="manifest"):
        reg.load("g")


def test_sharded_warm_start_matches_fingerprint(rng, tmp_path):
    """load_or_prepare_sharded restores mutated state when the caller's COO
    matches the stored fingerprint, and prepares fresh when it doesn't."""
    from repro.launch.mesh import make_spmm_mesh

    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    mesh = make_spmm_mesh(1)
    dp = reg.load_or_prepare_sharded("g", rows, cols, vals, a.shape, mesh,
                                     CFG, shard_axis="rows")
    dense = a.astype(np.float64).copy()
    zr, zc = np.nonzero(dense == 0)
    dp.update(GraphDelta.inserts(zr[:2], zc[:2], [7.0, -3.0]))
    dense[zr[:2], zc[:2]] += [7.0, -3.0]
    reg.save("g", dp)

    # the fingerprint binds to the *evolved* logical matrix (to_coo), so a
    # caller re-registering that state warm-starts with the overlay intact
    er, ec, ev = dp.to_coo()
    warm = reg.load_or_prepare_sharded("g", er, ec, ev, a.shape, mesh,
                                       CFG, shard_axis="rows")
    assert warm.delta_nnz == 2
    b = jnp.asarray(rng.randn(a.shape[1], 8).astype(np.float32))
    out = np.asarray(warm.execute(b))
    expect = dense @ np.asarray(b, np.float64)
    assert np.abs(out - expect).max() / (np.abs(expect).max() + 1e-9) < 1e-4

    # different COO -> fresh prepare, no stale overlay
    vals2 = vals.copy()
    vals2[0] += 1.0
    cold = reg.load_or_prepare_sharded("g2", rows, cols, vals2, a.shape,
                                       mesh, CFG, shard_axis="rows")
    assert cold.delta_nnz == 0


def test_service_warm_starts_from_registry(rng, tmp_path):
    """The acceptance path: a new service process restores from disk
    without calling prepare() and serves correct results immediately."""
    a, rows, cols, vals = _graph(rng)
    b = rng.randn(64, 8).astype(np.float32)

    reg = PlanRegistry(str(tmp_path))
    svc1 = SpmmService(CFG, max_batch=4, registry=reg)
    svc1.register("g", rows, cols, vals, a.shape)
    t = svc1.submit("g", b)
    svc1.flush()
    want = np.asarray(svc1.fetch(t))

    # "restart": a fresh service over the same registry
    svc2 = SpmmService(CFG, max_batch=4, registry=reg)
    before = spmm.prepare_call_count()
    svc2.register("g", rows, cols, vals, a.shape)
    assert spmm.prepare_call_count() == before  # warm start, no prepare
    assert svc2.stats.warm_starts == 1
    t2 = svc2.submit("g", b)
    svc2.flush()
    assert np.array_equal(np.asarray(svc2.fetch(t2)), want)

    # name-only restore (no COO in hand at startup)
    svc3 = SpmmService(CFG, max_batch=4, registry=reg)
    before = spmm.prepare_call_count()
    svc3.warm_start("g")
    assert spmm.prepare_call_count() == before
    t3 = svc3.submit("g", b)
    svc3.flush()
    assert np.array_equal(np.asarray(svc3.fetch(t3)), want)


def test_service_updates_persist_across_restart(rng, tmp_path):
    a, rows, cols, vals = _graph(rng)
    b = rng.randn(64, 8).astype(np.float32)
    reg = PlanRegistry(str(tmp_path))
    svc1 = SpmmService(CFG, max_batch=4, registry=reg)
    svc1.register("g", rows, cols, vals, a.shape)
    dense = np.zeros(a.shape, np.float64)
    np.add.at(dense, (rows, cols), vals)
    zr, zc = np.nonzero(dense == 0)
    pick = rng.choice(zr.size, 5, replace=False)
    iv = rng.randn(5)
    svc1.update_matrix("g", GraphDelta.inserts(zr[pick], zc[pick], iv))
    dense[zr[pick], zc[pick]] += iv

    svc2 = SpmmService(CFG, max_batch=4, registry=reg)
    before = spmm.prepare_call_count()
    svc2.warm_start("g")
    assert spmm.prepare_call_count() == before
    t = svc2.submit("g", b)
    svc2.flush()
    out = np.asarray(svc2.fetch(t))
    expect = dense @ np.asarray(b, np.float64)
    assert np.abs(out - expect).max() / (np.abs(expect).max() + 1e-9) < 1e-4


def test_registry_retention_keeps_newest(rng, tmp_path):
    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path), keep=2)
    dp = DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG))
    for _ in range(4):
        reg.save("g", dp)
    d = os.path.join(str(tmp_path), "g")
    steps = [x for x in os.listdir(d) if x.startswith("step_")]
    assert len(steps) == 2  # checkpoint-style GC
    reg.load("g")  # newest entry still loads


# ---------------------------------------------------------------------------
# write-path crash consistency: a save that dies mid-write must leave the
# previous generation as the loadable latest step (atomic tmp + os.replace)
# ---------------------------------------------------------------------------
def test_crash_during_shard_write_preserves_previous_generation(
        rng, tmp_path, monkeypatch):
    from repro.checkpoint import checkpoint as ckpt

    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    dp = DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG))
    reg.save("g", dp)

    real_save, calls = np.save, []

    def dying_save(path, arr, **kw):
        calls.append(path)
        if len(calls) >= 2:  # first shard lands, the next write crashes
            raise OSError("disk died mid-shard")
        return real_save(path, arr, **kw)

    monkeypatch.setattr(ckpt.np, "save", dying_save)
    with pytest.raises(RegistryError, match="persist"):
        reg.save("g", dp)
    monkeypatch.setattr(ckpt.np, "save", real_save)

    # the half-written generation never replaced into place: generation 1
    # is still the latest step and loads without any fallback
    b = jnp.asarray(rng.randn(64, 8).astype(np.float32))
    restored = reg.load("g")
    assert np.array_equal(np.asarray(restored.execute(b)),
                          np.asarray(dp.execute(b)))
    assert reg.generation_fallbacks == 0


def test_interrupted_manifest_replace_preserves_previous_generation(
        rng, tmp_path, monkeypatch):
    from repro.checkpoint import checkpoint as ckpt

    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path))
    dp = DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG))
    reg.save("g", dp)

    def dying_replace(src, dst):
        raise OSError("power loss during rename")

    monkeypatch.setattr(ckpt.os, "replace", dying_replace)
    with pytest.raises(RegistryError, match="persist"):
        reg.save("g", dp)
    monkeypatch.undo()

    restored = reg.load("g")
    assert restored.plan.shape == a.shape
    assert reg.generation_fallbacks == 0


def test_corrupt_newest_generation_falls_back_with_warning(rng, tmp_path):
    import warnings

    a, rows, cols, vals = _graph(rng)
    reg = PlanRegistry(str(tmp_path), keep=2)
    dp = DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, CFG))
    b = jnp.asarray(rng.randn(64, 8).astype(np.float32))
    want = np.asarray(dp.execute(b))
    reg.save("g", dp)
    reg.save("g", dp)

    # mangle the newest generation the way a torn write would
    with open(os.path.join(_entry_dir(str(tmp_path), "g"),
                           "manifest.json"), "w") as f:
        f.write('{"meta": {')

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        restored = reg.load("g")
    assert np.array_equal(np.asarray(restored.execute(b)), want)
    assert reg.generation_fallbacks == 1
    assert any(issubclass(w.category, RuntimeWarning)
               and "serving step_" in str(w.message) for w in caught)

    # once every retained generation is damaged, the failure aggregates
    with open(os.path.join(str(tmp_path), "g", "step_000000001",
                           "manifest.json"), "w") as f:
        f.write("not json")
    with pytest.raises(RegistryError, match="every retained generation"):
        reg.load("g")
