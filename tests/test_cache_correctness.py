"""Cache correctness: signature uniqueness across tier/batch/shard variants
and retrace-count guarantees for the batched executor.

A signature collision would silently hand a plan to another plan's compiled
executor (wrong static shapes/kernels); a retrace leak would recompile per
call.  Both are invisible to output-correctness tests, so they get their
own suite.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import spmm
from repro.launch.mesh import make_spmm_mesh
from conftest import make_sparse


def _fringe_problem(rng, m=60, k=96, nnz=400):
    rows = rng.randint(0, m, nnz)
    cols = rng.randint(0, k, nnz)
    vals = rng.randn(nnz).astype(np.float32)
    return rows.astype(np.int64), cols.astype(np.int64), vals, (m, k)


# ---------------------------------------------------------------------------
# signature uniqueness
# ---------------------------------------------------------------------------
def test_signatures_unique_across_tier_and_shard_variants(rng):
    """Tier variants, the sharded rows/rhs variants, and the plain plan all
    carry distinct cache keys — no fused-executor aliasing."""
    rows, cols, vals, shape = _fringe_problem(rng)
    mk = lambda budget: spmm.prepare(
        rows, cols, vals, shape,
        spmm.SpmmConfig(impl="pallas_interpret", bn=128, alpha=1.0,
                        fringe_vmem_budget=budget))
    resident, ksharded, xla = mk(None), mk(60_000), mk(4_096)
    assert {p.fringe_tier for p in (resident, ksharded, xla)} == {
        "resident", "ksharded", "xla"}

    mesh = make_spmm_mesh(1)
    cfg = spmm.SpmmConfig(impl="xla")
    plain = spmm.prepare(rows, cols, vals, shape, cfg)
    srows = spmm.prepare_sharded(rows, cols, vals, shape, mesh, cfg,
                                 shard_axis="rows")
    srhs = spmm.prepare_sharded(rows, cols, vals, shape, mesh, cfg,
                                shard_axis="rhs")
    sigs = [p.signature() for p in (resident, ksharded, xla,
                                    plain, srows, srhs)]
    assert len(set(sigs)) == len(sigs)


def test_batched_cache_key_includes_batch(rng):
    """The batched executor is cached per (signature, batch): distinct batch
    sizes never share one compiled program object."""
    from repro.exec import build_executor

    rows, cols, vals, shape = _fringe_problem(rng)
    plan = spmm.prepare(rows, cols, vals, shape, spmm.SpmmConfig(impl="xla"))
    sig = plan.signature()
    fn2 = build_executor(sig, batch=2)
    fn3 = build_executor(sig, batch=3)
    assert fn2 is not fn3
    assert build_executor(sig, batch=2) is fn2  # cache hit


# ---------------------------------------------------------------------------
# retrace counts
# ---------------------------------------------------------------------------
def test_batched_executor_traces_once_per_signature_and_batch(rng):
    a, rows, cols, vals = make_sparse(rng, 120, 100, 0.06, n_dense_rows=4)
    cfg = spmm.SpmmConfig(impl="xla")
    plan = spmm.prepare(rows, cols, vals, a.shape, cfg)
    b3 = jnp.asarray(rng.randn(3, 100, 24).astype(np.float32))
    spmm.execute(plan, b3).block_until_ready()  # trace (sig, batch=3)
    before = spmm.fused_trace_count()
    for _ in range(4):  # same (signature, batch): zero retraces
        spmm.execute(plan, b3).block_until_ready()
    # a re-prepared identical plan reuses the cached batched executor
    plan2 = spmm.prepare(rows, cols, vals, a.shape, cfg)
    assert plan2.signature() == plan.signature()
    spmm.execute(plan2, b3).block_until_ready()
    assert spmm.fused_trace_count() == before

    # a new batch size is exactly one legitimate retrace
    b5 = jnp.asarray(rng.randn(5, 100, 24).astype(np.float32))
    spmm.execute(plan, b5).block_until_ready()
    assert spmm.fused_trace_count() == before + 1
    spmm.execute(plan, b5).block_until_ready()
    assert spmm.fused_trace_count() == before + 1


def test_batched_and_unbatched_paths_do_not_alias(rng):
    """(K, N) and (1, K, N) operands produce equal math through separate
    cache entries, and neither retraces the other."""
    a, rows, cols, vals = make_sparse(rng, 80, 64, 0.08)
    plan = spmm.prepare(rows, cols, vals, a.shape,
                        spmm.SpmmConfig(impl="xla"))
    b = jnp.asarray(rng.randn(64, 16).astype(np.float32))
    flat = np.asarray(spmm.execute(plan, b))
    batched = np.asarray(spmm.execute(plan, b[None]))
    np.testing.assert_allclose(batched[0], flat, rtol=1e-6, atol=1e-6)
    before = spmm.fused_trace_count()
    spmm.execute(plan, b).block_until_ready()
    spmm.execute(plan, b[None]).block_until_ready()
    assert spmm.fused_trace_count() == before
