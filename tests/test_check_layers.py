"""The CI layering guard: the repo is clean, and violations are caught."""
import ast
import os
import sys

TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
sys.path.insert(0, TOOLS)

import check_layers  # noqa: E402


def test_repo_import_layering_is_clean():
    assert check_layers.check_tree() == []


def test_guard_catches_upward_static_import():
    tree = ast.parse("from ..exec.pipeline import build_executor\n")
    hits = list(check_layers.iter_imports("repro/core/foo.py", tree))
    assert hits == [(1, "repro.exec.pipeline")]


def test_guard_catches_stringly_imports():
    """importlib.import_module with a literal is scanned too — the lazy
    facade cannot be silently replicated elsewhere."""
    src = "import importlib\nimportlib.import_module('repro.serve')\n"
    hits = list(check_layers.iter_imports("repro/core/foo.py", ast.parse(src)))
    assert (2, "repro.serve") in hits


def test_facade_allowance_is_exactly_one_pair():
    assert check_layers.ALLOWED == {("repro/core/spmm.py", "repro.exec.api")}


def test_obs_is_a_bottom_layer():
    """obs may import nothing from repro except itself; everything above —
    including robust — may import it."""
    assert check_layers.FORBIDDEN["obs"] == ("repro",)
    assert check_layers.ALLOWED_PREFIXES["obs"] == ("repro.obs",)
    assert "repro.obs" in check_layers.ALLOWED_PREFIXES["robust"]


def test_guard_catches_obs_importing_upward():
    tree = ast.parse("from ..core import plan_ir\n")
    hits = list(check_layers.iter_imports("repro/obs/metrics.py", tree))
    assert hits == [(1, "repro.core")]
    # and the rule set flags it: repro.core matches the "repro" prefix and
    # no obs allowance covers it
    assert not any(
        "repro.core".startswith(p)
        for p in check_layers.ALLOWED_PREFIXES["obs"]
    )
