"""The CI layering guard: the repo is clean, and violations are caught."""
import ast
import os
import sys

TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
sys.path.insert(0, TOOLS)

import check_layers  # noqa: E402


def test_repo_import_layering_is_clean():
    assert check_layers.check_tree() == []


def test_guard_catches_upward_static_import():
    tree = ast.parse("from ..exec.pipeline import build_executor\n")
    hits = list(check_layers.iter_imports("repro/core/foo.py", tree))
    assert hits == [(1, "repro.exec.pipeline")]


def test_guard_catches_stringly_imports():
    """importlib.import_module with a literal is scanned too — the lazy
    facade cannot be silently replicated elsewhere."""
    src = "import importlib\nimportlib.import_module('repro.serve')\n"
    hits = list(check_layers.iter_imports("repro/core/foo.py", ast.parse(src)))
    assert (2, "repro.serve") in hits


def test_facade_allowance_is_exactly_one_pair():
    assert check_layers.ALLOWED == {("repro/core/spmm.py", "repro.exec.api")}
