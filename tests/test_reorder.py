"""Global-local reordering (paper §6.1): permutation validity + density."""
import numpy as np
from _hyp import given, settings, st

from repro.core import reorder


def _clustered_matrix(seed=0, n_clusters=4, rows_per=32, cols_per=32, noise=0.01):
    """Block-community matrix, rows/cols shuffled — global reorder should
    recover the communities."""
    r = np.random.RandomState(seed)
    m = k = n_clusters * rows_per
    a = (r.rand(m, k) < noise).astype(np.float32)
    for c in range(n_clusters):
        sl = slice(c * rows_per, (c + 1) * rows_per)
        a[sl, sl] = (r.rand(rows_per, cols_per) < 0.4)
    rp, cp = r.permutation(m), r.permutation(k)
    a = a[rp][:, cp]
    rows, cols = np.nonzero(a)
    return a, rows, cols


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50))
def test_row_order_is_permutation(seed):
    r = np.random.RandomState(seed)
    m, k = 48, 64
    a = (r.rand(m, k) < 0.1)
    rows, cols = np.nonzero(a)
    res = reorder.reorder(rows, cols, (m, k), bm=8, bk=8)
    assert sorted(res.row_order.tolist()) == list(range(m))
    assert sorted(res.col_order.tolist()) == list(range(k))


def test_density_improves_on_clustered():
    a, rows, cols = _clustered_matrix()
    base = reorder.density_improvement(rows, cols, a.shape, 16, 16)
    res = reorder.reorder(rows, cols, a.shape, bm=16, bk=16,
                          reorder_cols=True)
    after = reorder.density_improvement(
        rows, cols, a.shape, 16, 16,
        row_order=res.row_order, col_order=res.col_order)
    assert after > base * 1.2, (base, after)


def test_local_only_refines_global():
    a, rows, cols = _clustered_matrix(seed=3)
    g = reorder.reorder(rows, cols, a.shape, bm=16, bk=16,
                        enable_local=False, reorder_cols=True)
    gl = reorder.reorder(rows, cols, a.shape, bm=16, bk=16,
                         enable_local=True, reorder_cols=True)
    d_g = reorder.density_improvement(rows, cols, a.shape, 16, 16,
                                      row_order=g.row_order,
                                      col_order=g.col_order)
    d_gl = reorder.density_improvement(rows, cols, a.shape, 16, 16,
                                       row_order=gl.row_order,
                                       col_order=gl.col_order)
    assert d_gl >= d_g * 0.95  # local must not destroy global gains


def test_empty_rows_handled():
    rows = np.array([0, 0, 5], np.int64)
    cols = np.array([1, 2, 3], np.int64)
    res = reorder.reorder(rows, cols, (10, 10), bm=4, bk=4)
    assert sorted(res.row_order.tolist()) == list(range(10))


def test_jaccard_windows_groups_similar_rows():
    # two row archetypes; windows of 4 should group same-archetype rows
    m, k = 16, 64
    a = np.zeros((m, k), np.float32)
    a[::2, :8] = 1.0    # even rows: cols 0-7
    a[1::2, 56:] = 1.0  # odd rows: cols 56-63
    rows, cols = np.nonzero(a)
    res = reorder.reorder(rows, cols, (m, k), bm=4, bk=8,
                          enable_global=False, reorder_cols=False)
    d = reorder.density_improvement(rows, cols, (m, k), 4, 8,
                                    row_order=res.row_order)
    d0 = reorder.density_improvement(rows, cols, (m, k), 4, 8)
    assert d >= d0 * 1.9, (d0, d)  # should roughly double (1.0 vs 0.5)
