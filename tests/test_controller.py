"""Fault-tolerant controller: restart, determinism, straggler detection."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import pipeline
from repro.models.config import ModelConfig
from repro.train import controller, optimizer as opt_lib, train_loop

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                  num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=128,
                  kv_chunk=16, compute_dtype=jnp.float32)
DCFG = pipeline.DataConfig(global_batch=4, seq_len=24, vocab_size=128)


def _setup(tmp_path, save_every=5):
    tcfg = train_loop.TrainConfig(
        optimizer=opt_lib.OptimizerConfig(lr=1e-3, warmup_steps=2,
                                          total_steps=100))
    params, opt = train_loop.init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    step = jax.jit(train_loop.make_train_step(CFG, tcfg))
    ccfg = controller.ControllerConfig(ckpt_dir=str(tmp_path),
                                       save_every=save_every)
    ctl = controller.TrainController(
        step, lambda s: jax.tree.map(jnp.asarray, pipeline.make_batch(DCFG, s)),
        ccfg)
    return params, opt, ctl


def test_restart_resumes_from_checkpoint(tmp_path):
    params, opt, ctl = _setup(tmp_path)
    p, o, log = ctl.run(params, opt, 16,
                        failure_at=lambda s: s == 12 and not ctl.restart_events)
    assert ctl.restart_events == [12]
    steps = [l["step"] for l in log]
    assert steps[-1] == 15
    # steps 10..12 replayed after restore from step-10 checkpoint
    assert steps.count(11) == 2


def test_restart_is_deterministic(tmp_path):
    """The replayed steps produce identical losses (deterministic data)."""
    params, opt, ctl = _setup(tmp_path)
    _, _, log = ctl.run(params, opt, 14,
                        failure_at=lambda s: s == 11 and not ctl.restart_events)
    by_step = {}
    replays = 0
    for l in log:
        if l["step"] in by_step:
            assert abs(by_step[l["step"]] - l["loss"]) < 1e-5
            replays += 1
        by_step[l["step"]] = l["loss"]
    assert replays > 0


def test_straggler_detection(tmp_path):
    params, opt, ctl = _setup(tmp_path, save_every=100)
    import time
    orig = ctl.train_step

    def slow_step(p, o, b, _n=[0]):
        _n[0] += 1
        if _n[0] == 12:
            time.sleep(1.0)
        return orig(p, o, b)

    ctl.train_step = slow_step
    ctl.run(params, opt, 14)
    assert len(ctl.straggler_events) >= 1


def test_gives_up_after_max_restarts(tmp_path):
    params, opt, ctl = _setup(tmp_path)
    ctl.cfg.max_restarts = 2
    import pytest
    with pytest.raises(controller.SimulatedFailure):
        ctl.run(params, opt, 10, failure_at=lambda s: s == 3)
