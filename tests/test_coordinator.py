"""Adaptive coordination (paper §5.3) + row-window balancing (paper §7)."""
import numpy as np
from _hyp import given, settings, st

from repro.core.coordinator import (
    AdaptiveCoordinator, balance_row_window_list, list_imbalance,
)
from repro.core.cost_model import EngineCostModel


def _simulate(coord, cm, max_epochs=30):
    for _ in range(max_epochs):
        st_ = coord.state
        t_m = cm.cost_matrix(max(st_.matrix_rows, 1), st_.k)
        t_v = cm.cost_vector(max(st_.vector_nnz, 1))
        coord.observe(t_m, t_v)
        if coord.converged():
            break
    return coord


def test_converges_from_extreme_skew_within_7_rounds():
    """Paper Fig. 18: bisection-style convergence, <=7 rounds from extremes."""
    rng = np.random.RandomState(0)
    cm = EngineCostModel(p_matrix=1e9, p_vector=5e6, r=1.0)
    nw = 200
    nnz = rng.randint(10, 2000, nw).astype(float)
    rows = np.full(nw, 128.0)
    for init in (np.ones(nw, bool), np.zeros(nw, bool)):
        coord = AdaptiveCoordinator(cm, nnz, rows, init.copy(), k=4096)
        _simulate(coord, cm)
        r = coord.rounds_to_converge()
        assert r is not None and r <= 7, r


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50), pm=st.floats(1e8, 1e10), pv=st.floats(1e5, 1e7))
def test_skew_never_increases_limit(seed, pm, pv):
    """Property: after convergence the skew stays within tolerance."""
    rng = np.random.RandomState(seed)
    cm = EngineCostModel(p_matrix=pm, p_vector=pv, r=1.0)
    nw = 100
    nnz = rng.randint(1, 3000, nw).astype(float)
    rows = np.full(nw, 64.0)
    coord = AdaptiveCoordinator(cm, nnz, rows, rng.rand(nw) < 0.5, k=2048)
    _simulate(coord, cm, max_epochs=40)
    if coord.converged():
        final = coord.history[-1].skew
        assert final <= 1.0 + coord.epsilon + 1e-9


def test_no_migration_when_balanced():
    cm = EngineCostModel(p_matrix=1.0, p_vector=1.0)
    coord = AdaptiveCoordinator(
        cm, np.ones(10), np.ones(10), np.zeros(10, bool), k=10)
    rec = coord.observe(1.0, 1.01)
    assert rec.migrated_windows == 0


def test_lpt_balances_power_law_windows():
    rng = np.random.RandomState(0)
    costs = rng.pareto(1.1, 500) + 0.1
    naive = [np.arange(i, 500, 24) for i in range(24)]
    lpt = balance_row_window_list(costs, 24)
    assert list_imbalance(lpt, costs) < list_imbalance(naive, costs)
    # LPT is within ~4/3 of the lower bound max(ideal, heaviest window)
    lower = max(1.0, costs.max() / (costs.sum() / 24))
    assert list_imbalance(lpt, costs) <= lower * 4 / 3 + 1e-9
    # every window assigned exactly once
    allw = np.concatenate(lpt)
    assert sorted(allw.tolist()) == list(range(500))
