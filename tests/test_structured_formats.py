"""Structured-sparsity fast lane: detection, packed round-trips, no-alias.

Property tests round-trip the N:M and bitmap tile payloads against the
flat stream they encode; detection tests pin the promote/reject rules
(tightest description wins, near-N:M rejected, duplicate COO entries
count once).  The end-to-end tests prove the acceptance invariants:
structured and general plans never alias one cached executor, the
existing general panel is bit-identical under auto selection, dynamic
core updates demote the packed payload instead of staling it, and the
tuner's tile-shape table is demote-only validated.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import formats, plan_ir, spmm, tuner
from repro.core.cost_model import EngineCostModel
from repro.dynamic import delta
from repro.errors import PlanBuildError
from conftest import make_sparse


def nm_coo(rng, m, k, n_pat, m_pat):
    """Exact N:M COO: n_pat nonzeros in every m_pat-wide group of every row."""
    gk = k // m_pat
    w = rng.rand(m, gk, m_pat)
    top = np.argsort(w, axis=2)[:, :, :n_pat]
    rows = np.repeat(np.arange(m), gk * n_pat)
    base = np.broadcast_to(np.arange(gk)[None, :, None] * m_pat, top.shape)
    cols = (base + top).reshape(-1)
    vals = rng.randn(rows.size).astype(np.float32)
    # exact zeros would vanish from the nonzero structure
    vals = np.where(np.abs(vals) < 1e-3, np.float32(1.0), vals)
    return rows.astype(np.int64), cols.astype(np.int64), vals.astype(np.float32)


def coo_dense(rows, cols, vals, shape):
    d = np.zeros(shape, np.float32)
    np.add.at(d, (rows, cols), vals)
    return d


def _nm_problem(rng, m=256, k=256, n=128, n_pat=1, m_pat=32):
    rows, cols, vals = nm_coo(rng, m, k, n_pat, m_pat)
    b = rng.randn(k, n).astype(np.float32)
    return rows, cols, vals, (m, k), b


# ---------------------------------------------------------------------------
# payload round-trips (property-based)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([(1, 4), (2, 8), (4, 16), (1, 32)]),
       st.integers(1, 3), st.sampled_from([8, 16]))
def test_nm_pack_round_trip(seed, pat, t, bm):
    """pack -> unpack is the identity on any stream satisfying the pattern."""
    n_pat, m_pat = pat
    rng = np.random.RandomState(seed)
    gk = 2
    g = rng.randn(t, bm, gk, m_pat).astype(np.float32)
    order = np.argsort(rng.rand(t, bm, gk, m_pat), axis=-1)
    keep = order < rng.randint(0, n_pat + 1, (t, bm, gk, 1))
    g = np.where(keep & (np.abs(g) > 1e-3), g, 0.0).astype(np.float32)
    flat = g.reshape(t, bm, gk * m_pat)
    nm_values, nm_codes = formats.pack_nm_tiles(flat, n_pat, m_pat)
    assert nm_values.shape == (t, bm, n_pat * gk)
    assert nm_codes.shape == (t, bm, gk)
    out = formats.unpack_nm_tiles(nm_values, nm_codes, n_pat, m_pat)
    np.testing.assert_array_equal(out, flat)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.5), st.integers(1, 3))
def test_bitmap_pack_round_trip(seed, density, t):
    """Bitmap pack -> unpack is the identity on arbitrary tile streams
    (bk=72 exercises a partial trailing 32-bit word)."""
    rng = np.random.RandomState(seed)
    bm, bk = 8, 72
    flat = ((rng.rand(t, bm, bk) < density)
            * rng.randn(t, bm, bk)).astype(np.float32)
    words, packed, row_cap = formats.pack_bitmap_tiles(flat)
    assert row_cap % 8 == 0 and row_cap >= 8
    assert words.shape == (t, bm, 3)
    out = formats.unpack_bitmap_tiles(words, packed, bk)
    np.testing.assert_array_equal(out, flat)


def test_bitmap_empty_tiles_round_trip():
    flat = np.zeros((2, 8, 64), np.float32)
    words, packed, row_cap = formats.pack_bitmap_tiles(flat)
    assert row_cap == 8
    assert not np.asarray(words).any()
    np.testing.assert_array_equal(
        formats.unpack_bitmap_tiles(words, packed, 64), flat)


def test_pack_nm_rejects_violating_stream():
    flat = np.zeros((1, 8, 32), np.float32)
    flat[0, 0, :3] = 1.0  # 3 nonzeros in the first 4-wide group
    with pytest.raises(ValueError, match="violates"):
        formats.pack_nm_tiles(flat, 2, 4)
    with pytest.raises(ValueError, match="multiple"):
        formats.pack_nm_tiles(np.zeros((1, 8, 30), np.float32), 1, 4)
    with pytest.raises(ValueError, match="packable range"):
        formats.pack_nm_tiles(np.zeros((1, 8, 32), np.float32), 5, 16)


# ---------------------------------------------------------------------------
# structure detection
# ---------------------------------------------------------------------------
def test_detect_exact_nm(rng):
    rows, cols, _ = nm_coo(rng, 64, 128, 2, 32)
    assert formats.detect_nm_pattern(rows, cols, (64, 128)) == (2, 32)


def test_detect_prefers_tightest_description(rng):
    """A 1:16 matrix is also an exact 2:32; the 32-wide description packs
    tighter ((n+1)/m = 3/32 vs 2/16), so it wins."""
    rows, cols, _ = nm_coo(rng, 32, 128, 1, 16)
    assert formats.detect_nm_pattern(rows, cols, (32, 128)) == (2, 32)


def test_detect_rejects_near_nm(rng):
    """One overfull group breaks every candidate: it inflates n past the
    packable bound at wide m and craters group fill at narrow m."""
    rows, cols, _ = nm_coo(rng, 64, 128, 1, 32)
    rows = np.concatenate([rows, np.zeros(6, np.int64)])
    cols = np.concatenate([cols, np.arange(32, 38, dtype=np.int64)])
    assert formats.detect_nm_pattern(rows, cols, (64, 128)) is None


def test_detect_duplicates_count_once(rng):
    rows, cols, _ = nm_coo(rng, 32, 64, 1, 16)
    r2, c2 = np.concatenate([rows, rows]), np.concatenate([cols, cols])
    assert (formats.detect_nm_pattern(r2, c2, (32, 64))
            == formats.detect_nm_pattern(rows, cols, (32, 64)))


def test_detect_empty_matrix():
    e = np.zeros(0, np.int64)
    assert formats.detect_nm_pattern(e, e, (16, 64)) is None
    assert formats.detect_block_diagonal(e, e, (256, 256)) is None


def test_detect_block_diagonal(rng):
    m = 256
    rows = np.arange(m, dtype=np.int64)
    cols = (rows // 64) * 64 + rng.randint(0, 64, m)
    # largest candidate wins: a 64-block diagonal is also a 128-block one
    assert formats.detect_block_diagonal(rows, cols, (m, m)) == 128
    cols2 = cols.copy()
    cols2[0] = 200  # one off-diagonal nonzero breaks every candidate
    assert formats.detect_block_diagonal(rows, cols2, (m, m)) is None


# ---------------------------------------------------------------------------
# end-to-end: fast lane correctness + no-alias
# ---------------------------------------------------------------------------
def test_auto_nm_fast_lane_matches_dense(rng):
    rows, cols, vals, shape, b = _nm_problem(rng)
    plan = spmm.prepare(rows, cols, vals, shape,
                        spmm.SpmmConfig(impl="xla", bn=128))
    assert plan.matrix_format == "nm"
    assert plan.format_params == (1, 32)
    out = np.asarray(spmm.execute(plan, jnp.asarray(b)))
    ref = coo_dense(rows, cols, vals, shape) @ b
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_bitmap_hint_matches_dense(rng):
    a, rows, cols, vals = make_sparse(rng, 256, 256, density=0.15)
    b = rng.randn(256, 128).astype(np.float32)
    plan = spmm.prepare(
        rows, cols, vals, a.shape,
        spmm.SpmmConfig(impl="xla", bn=128, structure_hint="bitmap"))
    assert plan.matrix_format == "bitmap"
    out = np.asarray(spmm.execute(plan, jnp.asarray(b)))
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("hint,fmt", [(None, "nm"), ("bitmap", "bitmap")])
def test_structured_kernels_match_oracle_interpret(rng, hint, fmt):
    """The pallas tile kernels (interpret mode) agree with the dense oracle
    through the full prepare/execute pipeline."""
    rows, cols, vals, shape, b = _nm_problem(rng, m=128, k=128)
    plan = spmm.prepare(
        rows, cols, vals, shape,
        spmm.SpmmConfig(impl="pallas_interpret", bn=128,
                        structure_hint=hint))
    assert plan.matrix_format == fmt
    out = np.asarray(spmm.execute(plan, jnp.asarray(b)))
    ref = coo_dense(rows, cols, vals, shape) @ b
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_structured_and_general_never_alias(rng):
    """Structured and general plans for the same matrix carry distinct
    signatures and hit distinct cached executors."""
    # unique dims: no other test shares this signature, so the trace-count
    # deltas below are deterministic under any test ordering
    rows, cols, vals, shape, b = _nm_problem(rng, m=320, k=192)
    cfg = spmm.SpmmConfig(impl="xla", bn=128)
    plan_s = spmm.prepare(rows, cols, vals, shape, cfg)
    plan_g = spmm.prepare(
        rows, cols, vals, shape,
        dataclasses.replace(cfg, structure_hint="general"))
    assert plan_s.matrix_format == "nm"
    assert plan_g.matrix_format == "general"
    sig_s, sig_g = plan_s.signature(), plan_g.signature()
    assert sig_s != sig_g
    assert plan_ir.sig_matrix_format(sig_s) == "nm"
    assert plan_ir.general_format_sig(sig_s) == sig_g

    bj = jnp.asarray(b)
    before = spmm.fused_trace_count()
    out_s = spmm.execute(plan_s, bj)
    assert spmm.fused_trace_count() == before + 1
    out_g = spmm.execute(plan_g, bj)
    assert spmm.fused_trace_count() == before + 2
    # both executors are now cached: re-execution does not retrace
    spmm.execute(plan_s, bj)
    spmm.execute(plan_g, bj)
    assert spmm.fused_trace_count() == before + 2
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_g),
                               rtol=1e-4, atol=1e-4)


BENCH_PANEL = ["cora", "wiki-RfA", "ogbn-arxiv", "pattern1", "human_gene1",
               "F1", "mouse_gene", "reddit"]


def test_bench_panel_stays_general_bit_identical():
    """Auto format selection leaves every existing panel entry on the
    general path: same signature (same cached executor) and bit-identical
    output as an explicit structure_hint="general" plan."""
    from repro.data import graphs

    rng = np.random.RandomState(3)
    cfg = spmm.SpmmConfig(impl="xla")
    for name in BENCH_PANEL:
        spec = graphs.PAPER_DATASETS[name]
        spec = dataclasses.replace(spec, m=min(spec.m, 256),
                                   k=min(spec.k, 256))
        rows, cols, vals = graphs.generate(spec)
        b = jnp.asarray(rng.randn(spec.k, 64).astype(np.float32))
        plan_a = spmm.prepare(rows, cols, vals, (spec.m, spec.k), cfg)
        plan_g = spmm.prepare(
            rows, cols, vals, (spec.m, spec.k),
            dataclasses.replace(cfg, structure_hint="general"))
        assert plan_a.matrix_format == "general", name
        assert plan_a.signature() == plan_g.signature(), name
        np.testing.assert_array_equal(
            np.asarray(spmm.execute(plan_a, b)),
            np.asarray(spmm.execute(plan_g, b)), err_msg=name)


# ---------------------------------------------------------------------------
# signature helpers + dynamic demotion
# ---------------------------------------------------------------------------
def test_xla_fallback_sig_keeps_format(rng):
    rows, cols, vals, shape, _ = _nm_problem(rng)
    sig = spmm.prepare(rows, cols, vals, shape,
                       spmm.SpmmConfig(impl="xla", bn=128)).signature()
    fb = plan_ir.xla_fallback_sig(sig)
    assert plan_ir.sig_impl(fb) == "xla"
    # health degradation swaps the impl only: the format survives
    assert plan_ir.sig_matrix_format(fb) == "nm"
    assert fb[plan_ir.SIG_FORMAT_PARAMS] == sig[plan_ir.SIG_FORMAT_PARAMS]

    g = plan_ir.general_format_sig(sig)
    assert plan_ir.sig_matrix_format(g) == "general"
    assert g[plan_ir.SIG_FORMAT_PARAMS] == (0, 0)
    assert plan_ir.general_format_sig(g) == g  # idempotent


def test_update_values_demotes_structured_core(rng):
    """Core value updates on a packed plan demote it to the general payload
    (the packed stream would go stale); results track the new values."""
    rows, cols, vals, shape, b = _nm_problem(rng)
    cfg = spmm.SpmmConfig(impl="xla", bn=128)
    plan = spmm.prepare(rows, cols, vals, shape, cfg)
    assert plan.matrix_format == "nm"

    idx = np.arange(vals.size)
    newv = (vals * 2.0).astype(np.float32)
    plan2 = delta.update_values(plan, idx, newv)
    assert plan2.matrix_format == "general"
    assert plan2.format_params == (0, 0)
    assert plan2.signature() == plan_ir.general_format_sig(plan.signature())
    out = np.asarray(spmm.execute(plan2, jnp.asarray(b)))
    ref = coo_dense(rows, cols, newv, shape) @ b
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    # the demotion happens once: later updates keep the general signature
    plan3 = delta.update_values(plan2, idx[:1], newv[:1] + 1.0)
    assert plan3.signature() == plan2.signature()


def test_registry_round_trip_keeps_structured_payload(rng, tmp_path):
    """A packed plan persists and restores with its payload, format, and
    signature intact (no silent demotion through the leaf serialization)."""
    from repro.dynamic import DynamicPlan
    from repro.dynamic.registry import PlanRegistry

    rows, cols, vals, shape, b = _nm_problem(rng)
    plan = spmm.prepare(rows, cols, vals, shape,
                        spmm.SpmmConfig(impl="xla", bn=128))
    assert plan.matrix_format == "nm"
    reg = PlanRegistry(str(tmp_path))
    reg.save("g", DynamicPlan(plan))
    warm = reg.load("g").plan
    assert warm.matrix_format == "nm"
    assert warm.signature() == plan.signature()
    np.testing.assert_array_equal(np.asarray(warm.nm_values),
                                  np.asarray(plan.nm_values))
    np.testing.assert_array_equal(np.asarray(warm.nm_codes),
                                  np.asarray(plan.nm_codes))
    np.testing.assert_allclose(
        np.asarray(spmm.execute(warm, jnp.asarray(b))),
        np.asarray(spmm.execute(plan, jnp.asarray(b))), rtol=1e-6)


# ---------------------------------------------------------------------------
# hint validation + reorder interaction
# ---------------------------------------------------------------------------
def test_explicit_nm_hint_violation_raises(rng):
    a, rows, cols, vals = make_sparse(rng, 256, 256, density=0.2)
    cfg = spmm.SpmmConfig(impl="xla", structure_hint=("nm", 1, 32))
    with pytest.raises(PlanBuildError, match="violates"):
        spmm.prepare(rows, cols, vals, a.shape, cfg)


def test_nm_hint_must_divide_bk(rng):
    a, rows, cols, vals = make_sparse(rng, 256, 256, density=0.2)
    cfg = spmm.SpmmConfig(impl="xla", structure_hint=("nm", 1, 5))
    with pytest.raises(PlanBuildError, match="dividing"):
        spmm.prepare(rows, cols, vals, a.shape, cfg)


def test_structured_hint_incompatible_with_reorder_cols(rng):
    rows, cols, vals, shape, _ = _nm_problem(rng)
    cfg = spmm.SpmmConfig(impl="xla", bn=128, reorder_cols=True,
                          structure_hint="nm")
    with pytest.raises(PlanBuildError, match="reorder_cols"):
        spmm.prepare(rows, cols, vals, shape, cfg)
    # unhinted detection under reorder_cols silently stays general: the
    # column permutation destroys group-local structure
    plan = spmm.prepare(rows, cols, vals, shape,
                        spmm.SpmmConfig(impl="xla", bn=128,
                                        reorder_cols=True))
    assert plan.matrix_format == "general"


# ---------------------------------------------------------------------------
# tuner: tile-shape table is demote-only validated
# ---------------------------------------------------------------------------
def test_tuned_tile_shape_demote_only():
    rates = dict(p_matrix=1e9, p_vector=1e8)

    ok = tuner.TunedCostModel(decisions={"tile_shape": [128, 64]}, **rates)
    assert ok.tile_shape(1000, 1000, 256, 5000) == (128, 64)
    # the analytic base never overrides the config's tile shape
    assert EngineCostModel(**rates).tile_shape(1000, 1000, 256, 5000) is None
    assert tuner.TunedCostModel(decisions={}, **rates).tile_shape(
        1000, 1000, 256, 5000) is None

    def shape_for(decision, m=1000, k=1000, n=256, nnz=5000):
        cm = tuner.TunedCostModel(
            decisions={"tile_shape": decision}, **rates)
        return cm.tile_shape(m, k, n, nnz)

    # misaligned choices are rejected, never adopted
    assert shape_for([100, 64]) is None   # bm not MXU-aligned
    assert shape_for([128, 60]) is None   # bk not sublane-aligned
    assert shape_for([0, 64]) is None
    # tiles larger than the padded operand are rejected
    assert shape_for([128, 256], k=64) is None
    assert shape_for([256, 64], m=64) is None
    # a working set past the VMEM budget is rejected
    assert shape_for([256, 256], m=4096, k=4096, n=100_000) is None
