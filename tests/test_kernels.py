"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dense_tile_spmm import dense_tile_spmm
from repro.kernels.gather_spmm import gather_spmm


def _block_stream(rng, num_windows, max_blocks, bm, bk, k_blocks, dtype):
    """Random flat tile stream (window-major sorted)."""
    steps_w, steps_c = [], []
    for w in range(num_windows):
        n = rng.randint(1, max_blocks + 1)
        steps_w += [w] * n
        steps_c += rng.choice(k_blocks, n, replace=False).tolist()
    t = len(steps_w)
    vals = rng.randn(t, bm, bk).astype(dtype)
    # sparsify tiles a bit
    vals *= (rng.rand(t, bm, bk) < 0.3)
    return (
        jnp.asarray(np.array(steps_w, np.int32)),
        jnp.asarray(np.array(steps_c, np.int32)),
        jnp.asarray(vals),
    )


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 128), (16, 32, 128), (128, 64, 256)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dense_tile_spmm_matches_ref(bm, bk, bn, dtype):
    rng = np.random.RandomState(0)
    num_windows, k_blocks = 3, 4
    sw, sc, vals = _block_stream(rng, num_windows, 3, bm, bk, k_blocks, np.float32)
    vals = vals.astype(dtype)
    b = jnp.asarray(rng.randn(k_blocks * bk, bn), dtype)
    out = dense_tile_spmm(sw, sc, vals, b, num_windows=num_windows,
                          bm=bm, bk=bk, bn=bn, interpret=True)
    expect = ref.ref_block_stream_spmm(sw, sc, vals, b, num_windows)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bn", [128, 256])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gather_spmm_matches_ref(bn, dtype):
    rng = np.random.RandomState(1)
    num_rows, kk, nnz = 6, 32, 40
    rows = np.sort(rng.randint(0, num_rows, nnz)).astype(np.int32)
    rows[:2] = 0
    rows[-2:] = num_rows - 1  # every packed row visited
    for r in range(num_rows):  # ensure all rows present
        if r not in rows:
            rows[rng.randint(nnz)] = r
    rows = np.sort(rows)
    cols = rng.randint(0, kk, nnz).astype(np.int32)
    vals = rng.randn(nnz).astype(np.float32)
    b = jnp.asarray(rng.randn(kk, bn), dtype)
    out = gather_spmm(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                      b, num_rows=num_rows, bn=bn, interpret=True)
    expect = ref.ref_gather_spmm(jnp.asarray(rows), jnp.asarray(cols),
                                 jnp.asarray(vals), b, num_rows)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_gather_spmm_duplicate_columns():
    """Consecutive same-col nonzeros (copy-elision path) accumulate correctly."""
    rows = jnp.asarray(np.array([0, 0, 0, 1], np.int32))
    cols = jnp.asarray(np.array([2, 2, 2, 2], np.int32))
    vals = jnp.asarray(np.array([1.0, 2.0, 3.0, 4.0], np.float32))
    b = jnp.asarray(np.eye(4, 128, dtype=np.float32) + 1.0)
    out = gather_spmm(rows, cols, vals, b, num_rows=2, bn=128, interpret=True)
    expect = ref.ref_gather_spmm(rows, cols, vals, b, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_ops_dispatch(impl):
    rng = np.random.RandomState(2)
    sw, sc, vals = _block_stream(rng, 2, 2, 8, 8, 3, np.float32)
    b = jnp.asarray(rng.randn(24, 128).astype(np.float32))
    out = ops.block_stream_spmm(sw, sc, vals, b, num_windows=2, bm=8, bk=8,
                                bn=128, impl=impl)
    expect = ref.ref_block_stream_spmm(sw, sc, vals, b, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5)


def test_zero_value_padding_steps_are_noops():
    """Padding tiles (window 0, block 0, zero values) must not perturb."""
    sw = jnp.asarray(np.array([0, 0], np.int32))
    sc = jnp.asarray(np.array([0, 1], np.int32))
    vals = jnp.asarray(np.stack([np.eye(8, 8), np.zeros((8, 8))]).astype(np.float32))
    b = jnp.asarray(np.random.RandomState(3).randn(16, 128).astype(np.float32))
    out = dense_tile_spmm(sw, sc, vals, b, num_windows=1, bm=8, bk=8, bn=128,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(b[:8]), rtol=1e-6)
