"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dense_tile_spmm import dense_tile_spmm
from repro.kernels.gather_spmm import gather_spmm, gather_spmm_ksharded


def _block_stream(rng, num_windows, max_blocks, bm, bk, k_blocks, dtype):
    """Random flat tile stream (window-major sorted)."""
    steps_w, steps_c = [], []
    for w in range(num_windows):
        n = rng.randint(1, max_blocks + 1)
        steps_w += [w] * n
        steps_c += rng.choice(k_blocks, n, replace=False).tolist()
    t = len(steps_w)
    vals = rng.randn(t, bm, bk).astype(dtype)
    # sparsify tiles a bit
    vals *= (rng.rand(t, bm, bk) < 0.3)
    return (
        jnp.asarray(np.array(steps_w, np.int32)),
        jnp.asarray(np.array(steps_c, np.int32)),
        jnp.asarray(vals),
    )


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 128), (16, 32, 128), (128, 64, 256)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dense_tile_spmm_matches_ref(bm, bk, bn, dtype):
    rng = np.random.RandomState(0)
    num_windows, k_blocks = 3, 4
    sw, sc, vals = _block_stream(rng, num_windows, 3, bm, bk, k_blocks, np.float32)
    vals = vals.astype(dtype)
    b = jnp.asarray(rng.randn(k_blocks * bk, bn), dtype)
    out = dense_tile_spmm(sw, sc, vals, b, num_windows=num_windows,
                          bm=bm, bk=bk, bn=bn, interpret=True)
    expect = ref.ref_block_stream_spmm(sw, sc, vals, b, num_windows)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bn", [128, 256])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gather_spmm_matches_ref(bn, dtype):
    rng = np.random.RandomState(1)
    num_rows, kk, nnz = 6, 32, 40
    rows = np.sort(rng.randint(0, num_rows, nnz)).astype(np.int32)
    rows[:2] = 0
    rows[-2:] = num_rows - 1  # every packed row visited
    for r in range(num_rows):  # ensure all rows present
        if r not in rows:
            rows[rng.randint(nnz)] = r
    rows = np.sort(rows)
    cols = rng.randint(0, kk, nnz).astype(np.int32)
    vals = rng.randn(nnz).astype(np.float32)
    b = jnp.asarray(rng.randn(kk, bn), dtype)
    out = gather_spmm(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                      b, num_rows=num_rows, bn=bn, interpret=True)
    expect = ref.ref_gather_spmm(jnp.asarray(rows), jnp.asarray(cols),
                                 jnp.asarray(vals), b, num_rows)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_gather_spmm_duplicate_columns():
    """Consecutive same-col nonzeros (copy-elision path) accumulate correctly."""
    rows = jnp.asarray(np.array([0, 0, 0, 1], np.int32))
    cols = jnp.asarray(np.array([2, 2, 2, 2], np.int32))
    vals = jnp.asarray(np.array([1.0, 2.0, 3.0, 4.0], np.float32))
    b = jnp.asarray(np.eye(4, 128, dtype=np.float32) + 1.0)
    out = gather_spmm(rows, cols, vals, b, num_rows=2, bn=128, interpret=True)
    expect = ref.ref_gather_spmm(rows, cols, vals, b, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


def _bucketed_stream(rng, num_rows, num_kb, bk, chunk, max_per_kb=6):
    """Hand-built k-bucketed fringe stream (the gather_spmm_ksharded layout):
    per-k-block row-sorted entries padded to a chunk multiple; empty
    k-blocks own no chunks.  Returns the stream plus the dense A it encodes."""
    kb_chunk, rows_l, cols_l, vals_l = [], [], [], []
    a = np.zeros((num_rows, num_kb * bk), np.float32)
    for kb in range(num_kb):
        cnt = rng.randint(0, max_per_kb + 1)
        if cnt == 0:
            continue
        r = np.sort(rng.randint(0, num_rows, cnt)).astype(np.int32)
        c = rng.randint(0, bk, cnt).astype(np.int32)
        v = rng.randn(cnt).astype(np.float32)
        np.add.at(a, (r, kb * bk + c), v)
        pad = ((cnt + chunk - 1) // chunk) * chunk - cnt
        rows_l.append(np.concatenate([r, np.zeros(pad, np.int32)]))
        cols_l.append(np.concatenate([c, np.zeros(pad, np.int32)]))
        vals_l.append(np.concatenate([v, np.zeros(pad, np.float32)]))
        kb_chunk += [kb] * ((cnt + chunk - 1) // chunk)
    return (
        jnp.asarray(np.array(kb_chunk, np.int32)),
        jnp.asarray(np.concatenate(rows_l)),
        jnp.asarray(np.concatenate(cols_l)),
        jnp.asarray(np.concatenate(vals_l)),
        a,
    )


@pytest.mark.parametrize("chunk", [1, 3, 4, 8])
def test_gather_spmm_ksharded_matches_refs(chunk):
    """K-sharded streaming kernel vs its k-blocked oracle and the dense
    answer; rows recur across k-blocks, so partial sums must merge in the
    resident output block across chunk steps."""
    rng = np.random.RandomState(chunk)
    num_rows, num_kb, bk = 6, 5, 8
    kb_chunk, rows, cols, vals, a = _bucketed_stream(
        rng, num_rows, num_kb, bk, chunk)
    b = jnp.asarray(rng.randn(num_kb * bk, 128).astype(np.float32))
    out = gather_spmm_ksharded(kb_chunk, rows, cols, vals, b,
                               num_rows=num_rows, bk=bk, bn=128,
                               interpret=True)
    oracle = ref.ref_gather_spmm_kblocked(kb_chunk, rows, cols, vals, b,
                                          num_rows, bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out), a @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_gather_spmm_ksharded_ragged_k():
    """K not a multiple of bk: the kernel pads B internally."""
    rng = np.random.RandomState(11)
    num_rows, num_kb, bk, chunk = 4, 3, 8, 2
    kb_chunk, rows, cols, vals, _ = _bucketed_stream(
        rng, num_rows, num_kb, bk, chunk, max_per_kb=4)
    k_ragged = num_kb * bk - 3
    # zero entries addressing the (padded-away) tail columns
    keep_cols = jnp.repeat(kb_chunk, chunk) * bk + cols < k_ragged
    vals = jnp.where(keep_cols, vals, 0.0)
    b = jnp.asarray(rng.randn(k_ragged, 128).astype(np.float32))
    out = gather_spmm_ksharded(kb_chunk, rows, cols, vals, b,
                               num_rows=num_rows, bk=bk, bn=128,
                               interpret=True)
    oracle = ref.ref_gather_spmm_kblocked(kb_chunk, rows, cols, vals, b,
                                          num_rows, bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


def test_densified_duplicate_pairs_accumulate():
    """Regression: hand-built streams may repeat a (window, k-block) pair;
    the add-based densify must accumulate both tiles (previously the last
    tile of a duplicated slot silently won)."""
    rng = np.random.RandomState(9)
    bm, bk = 8, 8
    sw = jnp.asarray(np.array([0, 0, 1, 0], np.int32))
    sc = jnp.asarray(np.array([1, 1, 0, 1], np.int32))  # slot (0,1) thrice
    vals = jnp.asarray(rng.randn(4, bm, bk).astype(np.float32))
    b = jnp.asarray(rng.randn(2 * bk, 128).astype(np.float32))
    out = ref.densified_block_stream_spmm(sw, sc, vals, b, 2)
    expect = ref.ref_block_stream_spmm(sw, sc, vals, b, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    # the duplicate stream is above the occupancy threshold, so the default
    # ops dispatch (no uniqueness guarantee) must also take the safe densify
    out_ops = ops.block_stream_spmm(sw, sc, vals, b, num_windows=2,
                                    bm=bm, bk=bk, bn=128, impl="xla")
    np.testing.assert_allclose(np.asarray(out_ops), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_densified_unique_matches_safe_on_unique_streams():
    """The fast plan-stream densify (index scatter + gather) agrees with
    the add-based one whenever pairs are unique."""
    rng = np.random.RandomState(10)
    sw, sc, vals = _block_stream(rng, 3, 3, 8, 8, 4, np.float32)
    b = jnp.asarray(rng.randn(32, 128).astype(np.float32))
    fast = ref.densified_block_stream_spmm_unique(sw, sc, vals, b, 3)
    safe = ref.densified_block_stream_spmm(sw, sc, vals, b, 3)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(safe),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_ops_dispatch(impl):
    rng = np.random.RandomState(2)
    sw, sc, vals = _block_stream(rng, 2, 2, 8, 8, 3, np.float32)
    b = jnp.asarray(rng.randn(24, 128).astype(np.float32))
    out = ops.block_stream_spmm(sw, sc, vals, b, num_windows=2, bm=8, bk=8,
                                bn=128, impl=impl)
    expect = ref.ref_block_stream_spmm(sw, sc, vals, b, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5)


def test_zero_value_padding_steps_are_noops():
    """Padding tiles (window 0, block 0, zero values) must not perturb."""
    sw = jnp.asarray(np.array([0, 0], np.int32))
    sc = jnp.asarray(np.array([0, 1], np.int32))
    vals = jnp.asarray(np.stack([np.eye(8, 8), np.zeros((8, 8))]).astype(np.float32))
    b = jnp.asarray(np.random.RandomState(3).randn(16, 128).astype(np.float32))
    out = dense_tile_spmm(sw, sc, vals, b, num_windows=1, bm=8, bk=8, bn=128,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(b[:8]), rtol=1e-6)
