"""End-to-end dry-run machinery on a small forced-device mesh (subprocess,
so the 512-device XLA flag never leaks into other tests)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # pin the host platform: autodetection can stall for minutes probing a
    # TPU runtime that isn't there (forced host device counts are a CPU
    # feature anyway)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_small_mesh_lower_compile_and_analyze():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import sharding as shd
        from repro.launch import hlo_analysis
        from repro.launch.mesh import make_debug_mesh
        from repro.models import model as M
        from repro.models.config import ModelConfig
        from repro.train import train_loop, optimizer as opt_lib

        mesh = make_debug_mesh(2, 4)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        rules = shd.AxisRules(batch_axes=("data",), fsdp_axes=("data",),
                              tp_axis="model")
        cfg = ModelConfig(name="tiny", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                          vocab_size=256, kv_chunk=32)
        tcfg = train_loop.TrainConfig()
        step = train_loop.make_train_step(cfg, tcfg)
        params = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(
            lambda: opt_lib.init_opt_state(params, tcfg.optimizer))
        pspecs = shd.param_specs(params, rules, sizes)
        ospecs = opt_lib.OptState(step=P(), m=shd.param_specs(opt.m, rules, sizes),
                                  v=shd.param_specs(opt.v, rules, sizes))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        bspec = {"tokens": P("data", None)}
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        def fn(p, o, b):
            with shd.use_rules(rules):
                return step(p, o, b)
        with mesh:
            compiled = jax.jit(
                fn, in_shardings=(ns(pspecs), ns(ospecs), ns(bspec)),
                out_shardings=(ns(pspecs), ns(ospecs),
                               jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                            {"loss": 0, "grad_norm": 0, "lr": 0})),
            ).lower(params, opt, batch).compile()
        mem = compiled.memory_analysis()
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per module
            ca = ca[0] if ca else {}
        print(json.dumps({
            "temp": mem.temp_size_in_bytes,
            "coll_count": coll["count"],
            "coll_total": sum(v for k, v in coll.items() if k != "count"),
            "flops": ca.get("flops", 0),
        }))
    """)
    rec = json.loads(_run(code).strip().splitlines()[-1])
    assert rec["temp"] > 0
    assert rec["coll_count"] > 0, "TP training must emit collectives"
    assert rec["coll_total"] > 0, "collective payload parsing broken"
    assert rec["flops"] > 0


def test_production_mesh_shapes():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh(multi_pod=False)
        m2 = make_production_mesh(multi_pod=True)
        print(m1.devices.shape, m1.axis_names)
        print(m2.devices.shape, m2.axis_names)
    """)
    out = _run(code)
    assert "(16, 16) ('data', 'model')" in out
    assert "(2, 16, 16) ('pod', 'data', 'model')" in out
