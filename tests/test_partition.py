"""Two-stage row/column extraction (paper §5.2.2) invariants."""
import numpy as np
from _hyp import given, settings, st

from repro.core import partition
from repro.core.cost_model import EngineCostModel
from conftest import make_sparse


def _cm(alpha):
    # synthetic model with the requested alpha
    return EngineCostModel(p_matrix=1.0, p_vector=alpha, r=1.0)


def test_nnz_conserved(rng):
    a, rows, cols, vals = make_sparse(rng, 100, 80, 0.05, n_dense_rows=5)
    part = partition.partition_rows_cols(rows, cols, vals, a.shape, _cm(0.1))
    assert part.nnz == len(rows)
    # reconstruct
    out = np.zeros(a.shape, np.float32)
    np.add.at(out, (part.core_rows, part.core_cols), part.core_vals)
    np.add.at(out, (part.fringe_rows, part.fringe_cols), part.fringe_vals)
    np.testing.assert_allclose(out, a, rtol=1e-6)


def test_alpha_extremes(rng):
    a, rows, cols, vals = make_sparse(rng, 60, 60, 0.1)
    all_fringe = partition.partition_rows_cols(
        rows, cols, vals, a.shape, _cm(1.0))
    assert all_fringe.core_nnz == 0
    all_core = partition.partition_rows_cols(
        rows, cols, vals, a.shape, _cm(1e-9), col_stage=False)
    assert all_core.fringe_nnz == 0


def test_row_threshold_semantics(rng):
    """Rows at or below Thres = alpha*K must be extracted (Eq. 4/5)."""
    m, k = 50, 100
    a = np.zeros((m, k), np.float32)
    a[:25, :2] = 1.0     # short rows: Len=2
    a[25:, :60] = 1.0    # long rows: Len=60
    rows, cols = np.nonzero(a)
    vals = a[rows, cols]
    part = partition.partition_rows_cols(
        rows, cols, vals, (m, k), _cm(0.1), col_stage=False)
    # alpha*K = 10: Len-2 rows -> fringe; Len-60 rows -> core
    assert set(np.unique(part.fringe_rows)) == set(range(25))
    assert set(np.unique(part.core_rows)) == set(range(25, 50))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 99), alpha=st.floats(0.001, 0.9))
def test_partition_property(seed, alpha):
    r = np.random.RandomState(seed)
    m = k = 40
    a = (r.rand(m, k) < 0.15) * r.randn(m, k)
    rows, cols = np.nonzero(a)
    vals = a[rows, cols]
    part = partition.partition_rows_cols(rows, cols, vals, (m, k), _cm(alpha))
    assert part.nnz == len(rows)
    assert part.core_nnz >= 0 and part.fringe_nnz >= 0
    # core rows really are the denser ones: every core row longer than thres
    if part.core_nnz:
        row_len = np.bincount(rows, minlength=m)
        core_rows = np.unique(part.core_rows)
        assert (row_len[core_rows] > part.row_threshold).all()


def test_migration_helpers(rng):
    a, rows, cols, vals = make_sparse(rng, 64, 64, 0.1, n_dense_rows=8)
    part = partition.partition_rows_cols(rows, cols, vals, a.shape, _cm(0.05))
    n0 = part.core_nnz
    row_window = np.arange(64) // 8
    moved = partition.migrate_core_to_fringe(
        part, np.array([0]), row_window)
    assert moved.nnz == part.nnz
    assert moved.core_nnz <= n0
    back = partition.migrate_fringe_to_core(moved, np.arange(8))
    assert back.nnz == part.nnz
