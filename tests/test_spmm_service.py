"""SpmmService: request batching, bucket padding, plan caching, results."""
import numpy as np
import pytest

from repro.core import spmm
from repro.data import graphs
from repro.dynamic import GraphDelta
from repro.errors import AdmissionError
from repro.launch.mesh import make_spmm_mesh
from repro.serve import SpmmService
from conftest import make_sparse


def _register(svc, rng, name="g", m=90, k=70):
    a, rows, cols, vals = make_sparse(rng, m, k, 0.08, n_dense_rows=3)
    svc.register(name, rows, cols, vals, a.shape)
    return a


def test_flush_returns_correct_results(rng):
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    a = _register(svc, rng)
    panels = [rng.randn(70, 16).astype(np.float32) for _ in range(6)]
    tickets = [svc.submit("g", p) for p in panels]
    assert svc.pending("g") == 6
    assert svc.flush() == 6
    assert svc.pending() == 0
    for t, p in zip(tickets, panels):
        got = np.asarray(svc.fetch(t))
        np.testing.assert_allclose(got, a @ p, rtol=1e-4, atol=1e-4)
    with pytest.raises(KeyError):  # fetch pops
        svc.fetch(tickets[0])


def test_bucket_padding_amortizes_traces(rng):
    """Ragged batch sizes pad up to power-of-two buckets, so flushes with
    1..max_batch pending requests share at most log2(max_batch)+1 traces."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    _register(svc, rng)
    b = rng.randn(70, 8).astype(np.float32)
    svc.submit("g", b)
    svc.submit("g", b)
    svc.submit("g", b)
    svc.flush()  # 3 requests -> one bucket-4 dispatch, 1 padded slot
    assert svc.stats.dispatches == 1
    assert svc.stats.padded_slots == 1
    before = spmm.fused_trace_count()
    for _ in range(3):  # any count <= 4 reuses the bucket-4 program
        svc.submit("g", b)
    svc.flush()
    assert spmm.fused_trace_count() == before


def test_oversized_queue_splits_into_groups(rng):
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=2)
    a = _register(svc, rng)
    panels = [rng.randn(70, 8).astype(np.float32) for _ in range(5)]
    tickets = [svc.submit("g", p) for p in panels]
    svc.flush()
    assert svc.stats.dispatches == 3  # 2 + 2 + 1(padded to 2)
    for t, p in zip(tickets, panels):
        np.testing.assert_allclose(np.asarray(svc.fetch(t)), a @ p,
                                   rtol=1e-4, atol=1e-4)


def test_mixed_width_requests_flush_correctly(rng):
    """Panels of different N for one matrix batch per shape group — a mixed
    stack used to raise mid-drain after dequeue, losing both requests."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    a = _register(svc, rng)
    p8 = rng.randn(70, 8).astype(np.float32)
    p16 = rng.randn(70, 16).astype(np.float32)
    t8, t16 = svc.submit("g", p8), svc.submit("g", p16)
    assert svc.flush() == 2
    np.testing.assert_allclose(np.asarray(svc.fetch(t8)), a @ p8,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(svc.fetch(t16)), a @ p16,
                               rtol=1e-4, atol=1e-4)
    assert svc.stats.dispatches == 2  # one per shape group


def test_submit_validates_operand(rng):
    svc = SpmmService(spmm.SpmmConfig(impl="xla"))
    _register(svc, rng)
    with pytest.raises(KeyError):
        svc.submit("unknown", np.zeros((70, 4), np.float32))
    with pytest.raises(ValueError, match="must be"):
        svc.submit("g", np.zeros((71, 4), np.float32))


def test_failed_dispatch_keeps_queue_intact(rng):
    """Requests leave the queue only after a successful dispatch: an
    execute-time failure must not strand tickets result-less."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    a = _register(svc, rng)
    p = rng.randn(70, 8).astype(np.float32)
    t = svc.submit("g", p)
    boom = RuntimeError("injected dispatch failure")
    orig = svc._execute
    svc._execute = lambda *args: (_ for _ in ()).throw(boom)
    with pytest.raises(RuntimeError, match="injected"):
        svc.flush()
    assert svc.pending("g") == 1  # still queued, not stranded
    svc._execute = orig
    svc.flush()
    np.testing.assert_allclose(np.asarray(svc.fetch(t)), a @ p,
                               rtol=1e-4, atol=1e-4)


def test_submit_rejects_indivisible_n_for_rhs_plan(rng):
    """rhs-sharded divisibility is enforced at submit, while the request is
    still the caller's problem (a flush-time raise would strand batches)."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    real = spmm.prepare_sharded(
        np.array([0], np.int64), np.array([0], np.int64),
        np.array([1.0], np.float32), (8, 8), make_spmm_mesh(1),
        spmm.SpmmConfig(impl="xla"), shard_axis="rhs")
    import dataclasses
    svc.register_sharded("g", dataclasses.replace(real, n_shards=4))
    with pytest.raises(ValueError, match="divisible"):
        svc.submit("g", np.zeros((8, 30), np.float32))
    svc.submit("g", np.zeros((8, 32), np.float32))  # divisible: accepted


def test_reregister_with_pending_requests_rejected(rng):
    svc = SpmmService(spmm.SpmmConfig(impl="xla"))
    a = _register(svc, rng)
    svc.submit("g", rng.randn(70, 8).astype(np.float32))
    with pytest.raises(AdmissionError, match="pending"):
        _register(svc, rng, m=50, k=40)


def test_non_pow2_max_batch_rounds_up(rng):
    """The log2(max_batch)+1 trace bound requires pow2 buckets; a non-pow2
    cap would add itself as an extra compiled batch size."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=6)
    assert svc.max_batch == 8
    a = _register(svc, rng)
    b = rng.randn(70, 8).astype(np.float32)
    ts = [svc.submit("g", b) for _ in range(6)]
    svc.flush()  # 6 requests pad to one bucket-8 dispatch
    assert svc.stats.dispatches == 1
    assert svc.stats.padded_slots == 2
    for t in ts:
        np.testing.assert_allclose(np.asarray(svc.fetch(t)), a @ b,
                                   rtol=1e-4, atol=1e-4)


def test_sharded_plan_backend(rng):
    """The same service front drains through a multi-device plan."""
    a, rows, cols, vals = make_sparse(rng, 90, 70, 0.08, n_dense_rows=3)
    cfg = spmm.SpmmConfig(impl="xla")
    splan = spmm.prepare_sharded(rows, cols, vals, a.shape, make_spmm_mesh(1),
                                 cfg, shard_axis="rows")
    svc = SpmmService(cfg, max_batch=2)
    svc.register_sharded("g", splan)
    p = rng.randn(70, 12).astype(np.float32)
    t = svc.submit("g", p)
    svc.flush()
    np.testing.assert_allclose(np.asarray(svc.fetch(t)), a @ p,
                               rtol=1e-4, atol=1e-4)


def test_per_matrix_flush_leaves_other_queues(rng):
    """flush(name=...) drains one queue; other matrices stay pending, so a
    dynamic update to one matrix never forces dispatching every queue."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    a = _register(svc, rng, name="g1")
    a2 = _register(svc, rng, name="g2", m=50, k=40)
    t1 = svc.submit("g1", rng.randn(70, 8).astype(np.float32))
    t2 = svc.submit("g2", rng.randn(40, 8).astype(np.float32))
    assert svc.flush(name="g1") == 1
    assert svc.pending("g1") == 0
    assert svc.pending("g2") == 1  # untouched
    svc.fetch(t1)
    with pytest.raises(KeyError, match="still queued"):
        svc.fetch(t2)
    with pytest.raises(KeyError, match="no matrix registered"):
        svc.flush(name="unknown")
    svc.flush(name="g2")
    svc.fetch(t2)


def test_fetch_raises_clear_keyerrors(rng):
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    _register(svc, rng)
    t = svc.submit("g", rng.randn(70, 8).astype(np.float32))
    with pytest.raises(KeyError, match="still queued"):
        svc.fetch(t)
    svc.flush()
    svc.fetch(t)
    with pytest.raises(KeyError, match="already fetched"):
        svc.fetch(t)
    with pytest.raises(KeyError, match="never issued"):
        svc.fetch(999)


def test_update_matrix_serves_mutated_results(rng):
    """update_matrix flushes that matrix's pre-update requests, applies the
    delta, and later submits see the mutated matrix."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    a = _register(svc, rng)
    dense = a.astype(np.float64).copy()
    p = rng.randn(70, 8).astype(np.float32)
    t_pre = svc.submit("g", p)

    rows, cols = np.nonzero(a)
    zr, zc = np.nonzero(a == 0)
    pick = rng.choice(zr.size, 6, replace=False)
    iv = rng.randn(6)
    delta = GraphDelta(
        ins_rows=zr[pick], ins_cols=zc[pick], ins_vals=iv,
        del_rows=rows[:4], del_cols=cols[:4],
    )
    stats = svc.update_matrix("g", delta)
    assert stats["delta_nnz"] >= 0
    # the pre-update request was drained against the OLD matrix
    np.testing.assert_allclose(np.asarray(svc.fetch(t_pre)), dense @ p,
                               rtol=1e-4, atol=1e-4)
    dense[zr[pick], zc[pick]] += iv
    dense[rows[:4], cols[:4]] = 0
    t_post = svc.submit("g", p)
    svc.flush()
    np.testing.assert_allclose(np.asarray(svc.fetch(t_post)), dense @ p,
                               rtol=1e-4, atol=1e-4)
    assert svc.stats.updates == 1
    with pytest.raises(KeyError):
        svc.update_matrix("nope", delta)


def test_reorder_cols_config_still_serves(rng):
    """reorder_cols plans can't carry a delta sidecar, but registering and
    serving them must keep working (update_matrix is what's unavailable)."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla", reorder_cols=True),
                      max_batch=2)
    a = _register(svc, rng)
    p = rng.randn(70, 8).astype(np.float32)
    t = svc.submit("g", p)
    svc.flush()
    np.testing.assert_allclose(np.asarray(svc.fetch(t)), a @ p,
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError, match="update"):
        svc.update_matrix("g", GraphDelta.deletes([0], [0]))


def test_update_matrix_over_mutation_stream(rng):
    """Drive the service with data.graphs.mutate — the dynamic-serving
    workload end to end, checked against a dense mirror every step."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    a = _register(svc, rng)
    dense = a.astype(np.float64).copy()
    rows, cols = np.nonzero(a)
    vals = a[rows, cols]
    p = rng.randn(70, 8).astype(np.float32)
    for delta in graphs.mutate(rows, cols, vals, a.shape, steps=4,
                               insert_frac=0.04, delete_frac=0.03,
                               update_frac=0.08, seed=5):
        svc.update_matrix("g", delta)
        for r, c, v in zip(delta.ins_rows, delta.ins_cols, delta.ins_vals):
            dense[r, c] += v
        for r, c in zip(delta.del_rows, delta.del_cols):
            dense[r, c] = 0.0
        for r, c, v in zip(delta.upd_rows, delta.upd_cols, delta.upd_vals):
            dense[r, c] = v
        t = svc.submit("g", p)
        svc.flush(name="g")
        np.testing.assert_allclose(np.asarray(svc.fetch(t)), dense @ p,
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# background (async) compaction
# ---------------------------------------------------------------------------
def _structural_overload(rng, a, frac=0.4):
    """A GraphDelta of zero-position inserts big enough to force a fold."""
    dense = a.astype(np.float64)
    zr, zc = np.nonzero(dense == 0)
    n = max(1, int(np.count_nonzero(dense) * frac))
    pick = rng.choice(zr.size, n, replace=False)
    iv = rng.randn(n)
    return GraphDelta.inserts(zr[pick], zc[pick], iv), (zr[pick], zc[pick], iv)


def test_async_compaction_never_blocks_serving(rng, monkeypatch):
    """A should_compact fold runs on the worker thread; submit/flush/fetch
    keep succeeding against the old plan + sidecar until the atomic swap."""
    import threading

    import repro.serve.spmm_service as svc_mod

    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    assert svc.async_compaction
    a = _register(svc, rng)
    dense = a.astype(np.float64).copy()

    real_build = svc_mod._compact_build
    started, release = threading.Event(), threading.Event()

    def slow_build(name, dplan, rows, cols, vals):
        started.set()
        assert release.wait(30), "test never released the fold"
        return real_build(name, dplan, rows, cols, vals)

    monkeypatch.setattr(svc_mod, "_compact_build", slow_build)

    delta, (ir, ic, iv) = _structural_overload(rng, a)
    stats = svc.update_matrix("g", delta)
    dense[ir, ic] += iv
    assert stats["compacted"] == 0  # nothing folded inline
    assert svc.stats.compactions_scheduled == 1
    assert started.wait(10), "fold never started on the worker"

    dp = svc.plan("g")
    p = rng.randn(70, 8).astype(np.float32)
    for _ in range(3):  # serving proceeds while the fold is deliberately stuck
        t = svc.submit("g", p)
        svc.flush(name="g")
        np.testing.assert_allclose(np.asarray(svc.fetch(t)), dense @ p,
                                   rtol=1e-4, atol=1e-4)
    assert dp.compactions == 0 and dp.delta_nnz > 0  # still pre-swap

    release.set()
    svc.drain_compactions(timeout=60)
    assert dp.compactions == 1 and dp.delta_nnz == 0
    assert svc.stats.compactions_applied == 1

    t = svc.submit("g", p)  # post-swap answers are unchanged
    svc.flush(name="g")
    np.testing.assert_allclose(np.asarray(svc.fetch(t)), dense @ p,
                               rtol=1e-4, atol=1e-4)
    svc.close()


def test_async_compaction_stale_snapshot_reschedules(rng, monkeypatch):
    """Mutations landing mid-fold make the snapshot stale: the finished
    fold is discarded (never swapped over newer state) and a fresh fold
    runs from the current matrix."""
    import threading

    import repro.serve.spmm_service as svc_mod

    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    a = _register(svc, rng)
    dense = a.astype(np.float64).copy()

    real_build = svc_mod._compact_build
    started, release = threading.Event(), threading.Event()

    def gated_build(name, dplan, rows, cols, vals):
        started.set()
        assert release.wait(30)
        return real_build(name, dplan, rows, cols, vals)

    monkeypatch.setattr(svc_mod, "_compact_build", gated_build)

    delta, (ir, ic, iv) = _structural_overload(rng, a)
    svc.update_matrix("g", delta)
    dense[ir, ic] += iv
    assert started.wait(10)

    # a second mutation lands while the first fold is in flight
    r0, c0 = int(ir[0]), int(ic[0])
    svc.update_matrix("g", GraphDelta.updates([r0], [c0], [9.5]))
    dense[r0, c0] = 9.5

    release.set()
    svc.drain_compactions(timeout=60)
    assert svc.stats.compactions_stale >= 1   # first fold was discarded
    assert svc.stats.compactions_applied >= 1  # rescheduled fold landed
    dp = svc.plan("g")
    assert dp.delta_nnz == 0

    p = rng.randn(70, 8).astype(np.float32)
    t = svc.submit("g", p)
    svc.flush(name="g")
    np.testing.assert_allclose(np.asarray(svc.fetch(t)), dense @ p,
                               rtol=1e-4, atol=1e-4)
    svc.close()


def test_sync_compaction_opt_out_folds_inline(rng):
    """async_compaction=False restores the old synchronous behavior: the
    fold happens inside update_matrix and is visible in its stats."""
    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4,
                      async_compaction=False)
    a = _register(svc, rng)
    delta, _ = _structural_overload(rng, a)
    stats = svc.update_matrix("g", delta)
    assert stats["compacted"] == 1
    assert svc.plan("g").compactions == 1
    assert svc.stats.compactions_scheduled == 0


def test_failed_fold_does_not_discard_other_folds(rng, monkeypatch):
    """A failed background build surfaces its error but never swallows
    another matrix's completed fold from the same poll batch."""
    import repro.serve.spmm_service as svc_mod

    svc = SpmmService(spmm.SpmmConfig(impl="xla"), max_batch=4)
    a_good = _register(svc, rng, name="good")
    _register(svc, rng, name="bad", m=88)
    dense = a_good.astype(np.float64).copy()

    real_build = svc_mod._compact_build

    def flaky_build(name, dplan, rows, cols, vals):
        if name == "bad":
            raise RuntimeError("injected build failure")
        return real_build(name, dplan, rows, cols, vals)

    monkeypatch.setattr(svc_mod, "_compact_build", flaky_build)

    dg, (ir, ic, iv) = _structural_overload(rng, a_good)
    svc.update_matrix("good", dg)
    dense[ir, ic] += iv
    db, _ = _structural_overload(rng, _dense_of(svc, "bad"))
    svc.update_matrix("bad", db)
    assert svc.stats.compactions_scheduled == 2

    # an unrelated matrix's flush never raises the bad fold's error — the
    # poll records it, adopts the good fold, and the drain surfaces it
    import time as _time

    deadline = _time.time() + 60
    p = rng.randn(70, 8).astype(np.float32)
    while svc.plan("good").compactions == 0 and _time.time() < deadline:
        t = svc.submit("good", p)
        svc.flush(name="good")  # must not raise "injected build failure"
        np.testing.assert_allclose(np.asarray(svc.fetch(t)), dense @ p,
                                   rtol=1e-4, atol=1e-4)
        _time.sleep(0.01)
    assert svc.plan("good").compactions == 1
    assert svc.plan("good").delta_nnz == 0
    with pytest.raises(RuntimeError, match="injected build failure"):
        svc.drain_compactions(timeout=60)
    assert svc.stats.compactions_failed == 1
    svc.close()


def _dense_of(svc, name):
    dp = svc.plan(name)
    maps = dp.maps
    dense = np.zeros(dp.shape, np.float64)
    np.add.at(dense, (maps.rows, maps.cols), maps.vals)
    return dense
