"""Fault-injection harness + degrade-to-XLA dispatch + registry seams.

Covers the robustness acceptance criteria that live below the serving
layer: harness determinism, per-signature health gating (bounded retry,
sticky demotion, bit-identical XLA fallback), and registry read/write
faults resolving to generational fallback or clean RegistryErrors.

Plans get unique shapes per test: the executor cache and jit trace caches
are process-wide, and the ``executor_build`` / ``pallas_lowering`` seams
fire per *build* / per *trace* — a shape reused from another test would
hit those caches and never reach the seam.
"""
import os
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan_ir, spmm
from repro.dynamic import DynamicPlan, PlanRegistry
from repro.errors import (
    DispatchError, FaultInjected, KernelLoweringError, RegistryError,
    ReproError,
)
from repro.exec.health import HEALTH
from repro.exec.pipeline import build_executor
from repro.robust.faults import HARNESS, SEAMS, armed, chaos_schedule
from conftest import make_sparse

CFG_KW = dict(bm=32, bk=16, bn=32)


@pytest.fixture(autouse=True)
def _clean_harness():
    HARNESS.reset()
    HEALTH.reset()
    yield
    HARNESS.reset()
    HEALTH.reset()


def _plan(rng, m, k, impl="xla", **cfg_kw):
    a, rows, cols, vals = make_sparse(rng, m, k, 0.08, n_dense_rows=2)
    cfg = spmm.SpmmConfig(impl=impl, **{**CFG_KW, **cfg_kw})
    return a, spmm.prepare(rows, cols, vals, a.shape, cfg)


def _xla_tier_ref(plan, b):
    """What the XLA fallback tier computes for this exact plan's leaves."""
    fsig = plan_ir.xla_fallback_sig(plan.signature())
    return build_executor(fsig, batch=None)(*plan_ir.plan_leaves(plan), b)


def _is_accel_sig(s):
    return isinstance(s, tuple) and plan_ir.sig_impl(s) not in (None, "xla")


# ---------------------------------------------------------------------------
# harness mechanics
# ---------------------------------------------------------------------------
def test_unknown_seam_rejected():
    with pytest.raises(ValueError, match="unknown fault seam"):
        HARNESS.arm("not_a_seam")
    with pytest.raises(ValueError, match="unknown fault seam"):
        HARNESS.calls("not_a_seam")
    assert "executor_build" in SEAMS and len(SEAMS) == 6


def test_disarmed_fire_only_counts():
    before = HARNESS.calls("dispatch")
    HARNESS.fire("dispatch", context="m")
    assert HARNESS.calls("dispatch") == before + 1
    assert HARNESS.fired("dispatch") == 0


def test_fail_once_fail_n_and_after_policies():
    HARNESS.arm("dispatch", times=2, after=1)
    HARNESS.fire("dispatch")  # after=1: first matching call passes
    for _ in range(2):
        with pytest.raises(FaultInjected):
            HARNESS.fire("dispatch")
    HARNESS.fire("dispatch")  # budget (times=2) exhausted
    assert HARNESS.fired("dispatch") == 2

    HARNESS.arm("dispatch", times=None)  # fail forever
    for _ in range(3):
        with pytest.raises(FaultInjected):
            HARNESS.fire("dispatch")


def test_match_predicate_filters_context_without_consuming_budget():
    HARNESS.arm("fold_build", times=1, match=lambda ctx: ctx == "bad")
    HARNESS.fire("fold_build", context="good")  # filtered: no fire
    HARNESS.fire("fold_build", context="good")
    with pytest.raises(FaultInjected):
        HARNESS.fire("fold_build", context="bad")
    HARNESS.fire("fold_build", context="bad")  # fail-once budget spent


def test_custom_exception_and_message():
    HARNESS.arm("registry_write", exc=OSError, message="disk full")
    with pytest.raises(OSError, match="disk full"):
        HARNESS.fire("registry_write")


def test_armed_context_manager_disarms_on_exit():
    with armed("dispatch"):
        assert "dispatch" in HARNESS.armed_seams()
        with pytest.raises(FaultInjected):
            HARNESS.fire("dispatch")
    assert "dispatch" not in HARNESS.armed_seams()
    HARNESS.fire("dispatch")  # disarmed again


def test_chaos_schedule_is_deterministic():
    s1 = chaos_schedule(1234)
    HARNESS.reset()
    s2 = chaos_schedule(1234)
    assert s1 == s2 and set(s1) == set(SEAMS)
    assert set(HARNESS.armed_seams()) == set(SEAMS)  # all armed fail-once
    counters = HARNESS.counters()
    assert set(counters) == {"calls", "fired"}


# ---------------------------------------------------------------------------
# degrade-to-XLA dispatch (acceptance: pallas failure -> bit-identical XLA)
# ---------------------------------------------------------------------------
def test_pallas_build_failure_degrades_bit_identically(rng):
    a, plan = _plan(rng, 72, 56, impl="pallas_interpret")
    b = jnp.asarray(rng.randn(56, 8).astype(np.float32))
    ref = _xla_tier_ref(plan, b)  # the tier the fallback must hit exactly
    np.testing.assert_allclose(  # and the tier itself is not vacuous
        np.asarray(ref, np.float64), a.astype(np.float64) @ np.asarray(b),
        rtol=1e-4, atol=1e-4)

    sig = plan.signature()
    with armed("executor_build", times=None, match=_is_accel_sig):
        out = spmm.execute(plan, b)  # serving never raises
        assert bool(jnp.array_equal(out, ref))  # bit-identical fallback
        assert HEALTH.state(sig) == "retrying"
        for _ in range(40):  # exhaust the bounded retry schedule
            assert bool(jnp.array_equal(spmm.execute(plan, b), ref))
    assert HEALTH.state(sig) == "demoted"  # sticky even once disarmed
    assert bool(jnp.array_equal(spmm.execute(plan, b), ref))
    snap = HEALTH.snapshot()
    assert snap["demotions"] == 1 and snap["fallbacks"] >= 41


def test_pallas_lowering_failure_degrades(rng):
    _, plan = _plan(rng, 68, 52, impl="pallas_interpret")
    b = jnp.asarray(rng.randn(52, 8).astype(np.float32))
    ref = _xla_tier_ref(plan, b)
    with armed("pallas_lowering", times=None):
        out = spmm.execute(plan, b)
    assert bool(jnp.array_equal(out, ref))
    assert HEALTH.is_degraded(plan.signature())


def test_transient_failure_recovers_inside_retry_window(rng):
    _, plan = _plan(rng, 60, 44, impl="pallas_interpret")
    b = jnp.asarray(np.random.RandomState(7).randn(44, 8).astype(np.float32))
    sig = plan.signature()
    with armed("pallas_lowering", times=1):  # single transient failure
        spmm.execute(plan, b)  # degrades this dispatch
        assert HEALTH.state(sig) == "retrying"
        # drive dispatches until the backoff window re-attempts the accel
        # tier; the seam is spent, so the retry succeeds and heals the sig
        for _ in range(6):
            spmm.execute(plan, b)
    assert HEALTH.state(sig) == "healthy"
    assert HEALTH.snapshot()["recoveries"] == 1


def test_degrade_disabled_surfaces_kernel_lowering_error(rng):
    _, plan = _plan(rng, 76, 40, impl="pallas_interpret",
                    degrade_to_xla=False)
    b = jnp.asarray(np.random.RandomState(3).randn(40, 8).astype(np.float32))
    with armed("pallas_lowering", times=None):
        with pytest.raises(KernelLoweringError, match="degrade_to_xla"):
            spmm.execute(plan, b)
    # KernelLoweringError is catchable as the taxonomy root
    assert issubclass(KernelLoweringError, ReproError)


def test_xla_plan_build_failure_propagates_fault(rng):
    """XLA-impl plans have no tier below them: a build fault propagates
    (typed), it cannot silently degrade to itself."""
    _, plan = _plan(rng, 84, 36, impl="xla")
    b = jnp.asarray(np.random.RandomState(5).randn(36, 8).astype(np.float32))
    with armed("executor_build", times=1):
        with pytest.raises(FaultInjected):
            spmm.execute(plan, b)
    out = spmm.execute(plan, b)  # failed builds are not cached: retry works
    assert out.shape == (84, 8)


def test_dispatch_error_when_every_tier_fails(rng):
    _, plan = _plan(rng, 92, 48, impl="pallas_interpret")
    b = jnp.asarray(np.random.RandomState(9).randn(48, 8).astype(np.float32))
    with armed("executor_build", times=None):  # no match: xla fails too
        with pytest.raises(DispatchError, match="every tier"):
            spmm.execute(plan, b)


# ---------------------------------------------------------------------------
# registry seams: write faults stay clean, read faults fall back a generation
# ---------------------------------------------------------------------------
def _dplan(rng, m=64, k=48):
    a, rows, cols, vals = make_sparse(rng, m, k, 0.08, n_dense_rows=2)
    cfg = spmm.SpmmConfig(impl="xla", **CFG_KW)
    return a, DynamicPlan(spmm.prepare(rows, cols, vals, a.shape, cfg))


def test_registry_write_fault_is_a_clean_registry_error(rng, tmp_path):
    a, dp = _dplan(rng)
    reg = PlanRegistry(str(tmp_path))
    reg.save("g", dp)
    with armed("registry_write"):
        with pytest.raises(RegistryError, match="persist"):
            reg.save("g", dp)
    # the previous generation still loads (atomic layout untouched)
    restored = reg.load("g")
    assert restored.plan.shape == a.shape
    assert reg.generation_fallbacks == 0


def test_registry_read_fault_falls_back_one_generation(rng, tmp_path):
    _, dp = _dplan(rng)
    reg = PlanRegistry(str(tmp_path), keep=2)
    reg.save("g", dp)
    reg.save("g", dp)  # two retained generations
    with armed("registry_read", times=1):  # newest read dies
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            restored = reg.load("g")
    assert restored is not None
    assert reg.generation_fallbacks == 1
    assert any("serving step_" in str(w.message) for w in caught)


def test_registry_read_fault_on_all_generations_aggregates(rng, tmp_path):
    _, dp = _dplan(rng)
    reg = PlanRegistry(str(tmp_path), keep=2)
    reg.save("g", dp)
    reg.save("g", dp)
    with armed("registry_read", times=None):
        with pytest.raises(RegistryError, match="every retained generation"):
            reg.load("g")


def test_chaos_seeded_schedule_smoke(rng):
    """The CI chaos leg's schedule builder composes with real dispatches:
    whatever fires surfaces as a typed ReproError, never a bare crash."""
    seed = int(os.environ.get("REPRO_CHAOS_SEED", "0")) % (2 ** 31)
    schedule = chaos_schedule(seed, max_offset=3)
    assert set(schedule) == set(SEAMS)
    _, plan = _plan(rng, 44, 28, impl="xla")
    b = jnp.asarray(np.random.RandomState(2).randn(28, 4).astype(np.float32))
    for _ in range(6):
        try:
            spmm.execute(plan, b)
        except ReproError:
            pass  # injected faults must surface typed
