"""Simulated-mesh parity worker (run in a subprocess with forced devices).

Asserts ``execute_sharded`` bit-parity (fp32 tolerance) against the
single-device ``execute`` on 1/2/4/8-way meshes, including uneven window
counts, an empty shard, the RHS axis, both pallas fringe tiers, the dataset
oracle panel, and batched operands.  Exits nonzero (via assertion) on any
mismatch; prints ``PARITY OK`` on success.

Launched by tests/test_sharded_executor.py through the ``forced_mesh_run``
conftest fixture, and runnable standalone:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \
        PYTHONPATH=src python tests/_sharded_parity_worker.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.hostdevices import force_host_device_count  # noqa: E402 (jax-free)

force_host_device_count(os.environ, 8)

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import spmm  # noqa: E402
from repro.data import graphs  # noqa: E402
from repro.launch.mesh import make_spmm_mesh  # noqa: E402

ORACLE_PANEL = ["cora", "F1", "reddit"]


def _synthetic(rng, m, k, density=0.08, dense_rows=0):
    a = (rng.rand(m, k) < density).astype(np.float32) * rng.randn(
        m, k
    ).astype(np.float32)
    if dense_rows:
        picks = rng.choice(m, dense_rows, replace=False)
        a[picks] = rng.randn(dense_rows, k).astype(np.float32)
    r, c = np.nonzero(a)
    return r.astype(np.int64), c.astype(np.int64), a[r, c], (m, k)


def _dataset(name, max_dim=512):
    spec = graphs.PAPER_DATASETS[name]
    spec = dataclasses.replace(spec, m=min(spec.m, max_dim),
                               k=min(spec.k, max_dim))
    rows, cols, vals = graphs.generate(spec)
    return rows, cols, vals, (spec.m, spec.k)


def check_parity(rows, cols, vals, shape, n_shards, tag, impl="xla",
                 shard_axis="rows", n=32, budget=None, batch=None):
    cfg = spmm.SpmmConfig(impl=impl, fringe_vmem_budget=budget)
    plan = spmm.prepare(rows, cols, vals, shape, cfg)
    rng = np.random.RandomState(7)
    if batch is None:
        b = jnp.asarray(rng.randn(shape[1], n).astype(np.float32))
    else:
        b = jnp.asarray(rng.randn(batch, shape[1], n).astype(np.float32))
    ref = np.asarray(spmm.execute(plan, b))
    splan = spmm.prepare_sharded(
        rows, cols, vals, shape, make_spmm_mesh(n_shards), cfg,
        shard_axis=shard_axis,
    )
    out = np.asarray(spmm.execute_sharded(splan, b))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5, err_msg=tag)
    print(f"ok {tag}: nsh={n_shards} axis={splan.shard_axis} impl={impl}")


def main():
    assert len(jax.devices()) >= 8, (
        f"worker needs 8 forced host devices, found {len(jax.devices())}"
    )
    rng = np.random.RandomState(0)

    # mesh-size sweep on a mixed core+fringe matrix (8 windows at bm=128)
    rows, cols, vals, shape = _synthetic(rng, 1000, 200, dense_rows=8)
    for nsh in (1, 2, 4, 8):
        check_parity(rows, cols, vals, shape, nsh, f"mesh{nsh}")
    # uneven window counts across shards: 8 windows over 3 shards
    check_parity(rows, cols, vals, shape, 3, "uneven-windows")
    # empty shard: one 100-row window, two shards
    r2, c2, v2, s2 = _synthetic(rng, 100, 64)
    check_parity(r2, c2, v2, s2, 2, "empty-shard")
    # RHS axis (replicated plan, sharded B columns)
    check_parity(rows, cols, vals, shape, 4, "rhs-axis", shard_axis="rhs")
    # pallas fringe tiers under interpret mode
    r3, c3, v3, s3 = _synthetic(rng, 300, 96)
    check_parity(r3, c3, v3, s3, 4, "interp-resident",
                 impl="pallas_interpret")
    check_parity(r3, c3, v3, s3, 4, "interp-ksharded",
                 impl="pallas_interpret", budget=40_000)
    # batched multi-RHS through the sharded executor, both axes
    check_parity(rows, cols, vals, shape, 8, "batched-rows", batch=3)
    check_parity(rows, cols, vals, shape, 8, "batched-rhs",
                 shard_axis="rhs", batch=3)
    # rhs-sharded plans reject an indivisible N instead of miscomputing
    splan = spmm.prepare_sharded(
        rows, cols, vals, shape, make_spmm_mesh(4),
        spmm.SpmmConfig(impl="xla"), shard_axis="rhs",
    )
    try:
        spmm.execute_sharded(splan, jnp.ones((shape[1], 30), jnp.float32))
    except ValueError as e:
        assert "divisible" in str(e), e
        print("ok rhs-indivisible-n rejected")
    else:
        raise AssertionError("indivisible N on a 4-shard rhs plan "
                             "must raise, not miscompute")
    # dataset oracle panel on the full 8-way mesh (acceptance criterion)
    for name in ORACLE_PANEL:
        check_parity(*_dataset(name), 8, f"panel-{name}")

    print("PARITY OK")


if __name__ == "__main__":
    main()
